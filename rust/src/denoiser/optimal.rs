//! The Optimal (exact empirical-Bayes) denoiser — Eq. 2 over the *entire*
//! training corpus (De Bortoli 2022). O(N·D) per evaluation: the paper's
//! scalability bottleneck and the memorisation-prone upper bound.

use super::softmax::StreamingSoftmax;
use super::{descale, sqdist, DenoiseResult, Denoiser, StepContext};
use crate::data::dataset::Dataset;

#[derive(Debug, Default)]
pub struct OptimalDenoiser;

impl OptimalDenoiser {
    pub fn new() -> Self {
        OptimalDenoiser
    }
}

impl Denoiser for OptimalDenoiser {
    fn name(&self) -> String {
        "optimal".into()
    }

    fn denoise(&mut self, x_t: &[f32], ctx: &StepContext) -> DenoiseResult {
        let ds = ctx.ds;
        let q = descale(x_t, ctx.alpha_bar());
        let scale = ctx.logit_scale();
        let mut acc = StreamingSoftmax::new(ds.d);
        let mut support = 0usize;
        // ascending support ids: on a streamed corpus this is a chunked
        // shard-at-a-time pass through the LRU, same push order — the
        // aggregate is bit-identical to the resident scan
        ds.visit_rows(ctx.rows(), |_, row| {
            acc.push(-sqdist(&q, row) * scale, row);
            support += 1;
        });
        let (f_hat, stats) = acc.finish();
        DenoiseResult {
            f_hat,
            stats,
            support,
        }
    }

    fn working_set_bytes(&self, ds: &Dataset) -> u64 {
        // full corpus + query/accumulator scratch
        (ds.n * ds.d + 2 * ds.d) as u64 * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;
    use crate::schedule::noise::{NoiseSchedule, ScheduleKind};

    fn setup() -> (Dataset, NoiseSchedule) {
        let mut spec = preset("mnist-sim").unwrap().clone();
        spec.n = 200;
        (
            Dataset::synthesize(&spec, 3),
            NoiseSchedule::new(ScheduleKind::DdpmLinear, 10),
        )
    }

    #[test]
    fn low_noise_memorizes_training_sample() {
        // The paper's memorisation pathology: at tiny noise the optimal
        // denoiser collapses onto the nearest training point.
        let (ds, sched) = setup();
        let mut den = OptimalDenoiser::new();
        let step = 9; // cleanest
        let a = sched.alpha_bar(step);
        let target = ds.row(17).to_vec();
        let x_t: Vec<f32> = target.iter().map(|&v| v * a.sqrt()).collect();
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step,
            class: None,
        };
        let out = den.denoise(&x_t, &ctx);
        let err: f32 = out
            .f_hat
            .iter()
            .zip(&target)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 0.05, "should memorise row 17, max err {err}");
        assert!(out.stats.top1_weight > 0.9);
        assert_eq!(out.support, ds.n);
    }

    #[test]
    fn high_noise_returns_corpus_mean() {
        let (ds, sched) = setup();
        let mut den = OptimalDenoiser::new();
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 0,
            class: None,
        };
        let x_t = vec![0.01f32; ds.d];
        let out = den.denoise(&x_t, &ctx);
        let mse: f32 = out
            .f_hat
            .iter()
            .zip(&ds.mean)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            / ds.d as f32;
        assert!(mse < 0.05, "high noise should blur to the mean, mse {mse}");
        assert!(out.stats.entropy > (ds.n as f32).ln() * 0.5);
    }

    #[test]
    fn conditional_restricts_support() {
        let (ds, sched) = setup();
        let mut den = OptimalDenoiser::new();
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 5,
            class: Some(2),
        };
        let out = den.denoise(&vec![0.0; ds.d], &ctx);
        assert_eq!(out.support, ds.class_rows[2].len());
    }
}
