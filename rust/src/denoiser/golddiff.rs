//! GoldDiff — Dynamic Time-Aware Golden Subset Diffusion (the paper's
//! contribution, Sec. 3.4), as a plug-and-play wrapper over any base
//! weighting:
//!
//! 1. **Adaptive Coarse Screening** (Eq. 4): top-m_t rows by the s=1/4
//!    downsampled-ℓ2 proxy distance (sharded scan in `index::scan`), with
//!    m_t *growing* as noise decreases.
//! 2. **Precision Golden Set Selection** (Eq. 5): exact full-resolution
//!    top-k_t inside the candidate pool, with k_t *shrinking* as noise
//!    decreases (Eq. 6).
//! 3. **Unbiased aggregation** (Sec. 3.2): a plain streaming softmax over
//!    the purified support — no weight-flattening tricks needed.
//!
//! `BaseWeighting` selects what Eq. 3's local operator is: plain pixel-space
//! logits (GoldDiff-on-Optimal), the PCA subspace (the paper's primary
//! configuration; `unbiased=false` gives the Tab. 6 WSS ablation arm), or
//! the Kamb patch weighting (Tab. 5).

use super::kamb::KambDenoiser;
use super::pca::PcaDenoiser;
use super::softmax::{ss_aggregate, PosteriorStats};
use super::{descale, sqdist, DenoiseResult, Denoiser, StepContext};
use crate::data::dataset::Dataset;
use crate::data::synthetic::proxy_embed;
use crate::index::scan::ProxyIndex;
use crate::schedule::budget::BudgetSchedule;
use crate::schedule::noise::NoiseSchedule;

/// The shared GoldDiff retrieval used by both the CPU reference path and
/// the XLA engine (`coordinator::xla_denoiser`).
///
/// Two regimes, per the paper's Integration→Selection analysis (Sec. 3.3):
///
/// * the **precision fraction** (1−g) of the budget comes from the
///   coarse→fine pipeline — proxy top-m_t then exact top-k (Eqs. 4–5);
/// * the **breadth fraction** g comes from a *stratified* sample of the
///   support (every ⌈n/k⌉-th row with a step-dependent offset; rows are in
///   iid order so this is an unbiased random subset). At high noise the
///   estimator is a Monte-Carlo integrator — "robust to retrieval
///   imprecision but sensitive to sample sparsity" — so nearest-only
///   selection would bias the global mean; the breadth rows restore it.
///
/// As g → 0 this degenerates to pure precision retrieval; as g → 1 to a
/// broad Monte-Carlo subset. Duplicates are skipped so exactly k distinct
/// rows return.
pub fn blended_golden_rows(
    index: &ProxyIndex,
    ctx: &StepContext,
    x_t: &[f32],
    m: usize,
    k: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Vec<u32> {
    let ds = ctx.ds;
    let g = ctx.sched.g(ctx.step) as f64;
    let k_breadth = ((k as f64) * g) as usize;
    let k_precise = k - k_breadth;

    let q = descale(x_t, ctx.alpha_bar());
    let mut rows: Vec<u32> = if k_precise > 0 {
        let qp = proxy_embed(&q, h, w, c);
        let cands = match ctx.class {
            Some(y) => index.top_m_class(ds, &qp, m, y),
            None => index.top_m(ds, &qp, m),
        };
        index.refine_top_k(ds, &q, &cands, k_precise)
    } else {
        Vec::new()
    };

    if k_breadth > 0 {
        // stratified fill over the (class-restricted) support
        let support: &[u32] = match ctx.class {
            Some(y) => &ds.class_rows[y as usize],
            None => &[],
        };
        let n = if ctx.class.is_some() {
            support.len()
        } else {
            ds.n
        };
        let mut seen: std::collections::HashSet<u32> = rows.iter().copied().collect();
        let stride = (n as f64 / k_breadth.max(1) as f64).max(1.0);
        let offset = (ctx.step as f64 * 0.618_033_99).fract() * stride;
        let mut pos = offset;
        while rows.len() < k && (pos as usize) < n {
            let idx = pos as usize;
            let gid = if ctx.class.is_some() {
                support[idx]
            } else {
                idx as u32
            };
            if seen.insert(gid) {
                rows.push(gid);
            }
            pos += stride;
        }
        // top up sequentially if strides collided with precise picks
        let mut idx = 0usize;
        while rows.len() < k && idx < n {
            let gid = if ctx.class.is_some() {
                support[idx]
            } else {
                idx as u32
            };
            if seen.insert(gid) {
                rows.push(gid);
            }
            idx += 1;
        }
    }
    rows
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaseWeighting {
    /// pixel-space Gaussian-kernel logits + unbiased SS
    Golden,
    /// PCA-subspace logits; `unbiased=false` = biased WSS (ablation)
    PcaSubspace { unbiased: bool },
    /// Kamb patch-based weighting restricted to the golden subset
    Kamb,
}

pub struct GoldDiff {
    pub base: BaseWeighting,
    pub budget: BudgetSchedule,
    pub index: ProxyIndex,
    h: usize,
    w: usize,
    c: usize,
    /// last step's budgets (telemetry)
    pub last_m: usize,
    pub last_k: usize,
}

impl GoldDiff {
    /// Paper defaults: m_min = k_max = N/10, m_max = N/4, k_min = N/20
    /// (Sec. 4.1), with the bucket ladder left un-padded on this CPU path
    /// (the XLA engine buckets via the manifest).
    pub fn paper_defaults(ds: &Dataset, _sched: &NoiseSchedule, base: BaseWeighting) -> GoldDiff {
        let buckets: Vec<usize> = (5..=17).map(|p| 1usize << p).collect();
        GoldDiff::new(ds, BudgetSchedule::paper_defaults(ds.n, &buckets), base)
    }

    pub fn new(ds: &Dataset, budget: BudgetSchedule, base: BaseWeighting) -> GoldDiff {
        GoldDiff {
            base,
            budget,
            index: ProxyIndex::default(),
            h: ds.h,
            w: ds.w,
            c: ds.c,
            last_m: 0,
            last_k: 0,
        }
    }

    /// The coarse→fine retrieval: returns the golden subset S_t (row ids,
    /// nearest-first) for a query at sampling point `step`.
    pub fn golden_subset(&mut self, x_t: &[f32], ctx: &StepContext) -> Vec<u32> {
        let b = self.budget.at(ctx.sched, ctx.step);
        self.last_m = b.m;
        self.last_k = b.k;
        blended_golden_rows(&self.index, ctx, x_t, b.m, b.k, self.h, self.w, self.c)
    }
}

impl Denoiser for GoldDiff {
    fn name(&self) -> String {
        match self.base {
            BaseWeighting::Golden => "golddiff".into(),
            BaseWeighting::PcaSubspace { unbiased: true } => "golddiff-pca".into(),
            BaseWeighting::PcaSubspace { unbiased: false } => "golddiff-wss".into(),
            BaseWeighting::Kamb => "golddiff-kamb".into(),
        }
    }

    fn denoise(&mut self, x_t: &[f32], ctx: &StepContext) -> DenoiseResult {
        let golden = self.golden_subset(x_t, ctx);
        let support = golden.len();
        let ds = ctx.ds;
        match self.base {
            BaseWeighting::Golden => {
                let q = descale(x_t, ctx.alpha_bar());
                let scale = ctx.logit_scale();
                let (f_hat, stats): (Vec<f32>, PosteriorStats) = ss_aggregate(
                    ds.d,
                    golden.iter().map(|&gid| {
                        let row = ds.row(gid as usize);
                        (-sqdist(&q, row) * scale, row)
                    }),
                );
                DenoiseResult {
                    f_hat,
                    stats,
                    support,
                }
            }
            BaseWeighting::PcaSubspace { unbiased } => {
                let mut base = PcaDenoiser::new(ds, unbiased);
                base.subset = Some(golden);
                let mut out = base.denoise(x_t, ctx);
                out.support = support;
                out
            }
            BaseWeighting::Kamb => {
                let mut base = KambDenoiser::new(ds);
                base.subset = Some(golden);
                let mut out = base.denoise(x_t, ctx);
                out.support = support;
                out
            }
        }
    }

    fn working_set_bytes(&self, ds: &Dataset) -> u64 {
        // proxy table + gathered golden subset + scratch — NOT the corpus
        // resident per-query working set (the corpus itself is shared,
        // dominant term is the m_max gather)
        (ds.n * ds.proxy_d + self.budget.m_max * ds.d + 4 * ds.d) as u64 * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;
    use crate::schedule::noise::ScheduleKind;

    fn setup() -> (Dataset, NoiseSchedule) {
        let mut spec = preset("cifar-sim").unwrap().clone();
        spec.n = 500;
        (
            Dataset::synthesize(&spec, 6),
            NoiseSchedule::new(ScheduleKind::DdpmLinear, 10),
        )
    }

    #[test]
    fn golden_subset_sizes_follow_schedule() {
        let (ds, sched) = setup();
        let mut gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden);
        let x = vec![0.1f32; ds.d];
        let ctx0 = StepContext {
            ds: &ds,
            sched: &sched,
            step: 0,
            class: None,
        };
        let s0 = gd.golden_subset(&x, &ctx0);
        let (m0, k0) = (gd.last_m, gd.last_k);
        let ctx9 = StepContext {
            ds: &ds,
            sched: &sched,
            step: 9,
            class: None,
        };
        let s9 = gd.golden_subset(&x, &ctx9);
        let (m9, k9) = (gd.last_m, gd.last_k);
        assert_eq!(s0.len(), k0);
        assert_eq!(s9.len(), k9);
        assert!(m9 > m0, "retrieval scope must grow: {m0} -> {m9}");
        assert!(k9 < k0, "aggregation budget must shrink: {k0} -> {k9}");
    }

    #[test]
    fn low_noise_golden_subset_contains_true_neighbour() {
        let (ds, sched) = setup();
        let mut gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden);
        let step = 9;
        let a = sched.alpha_bar(step);
        let x_t: Vec<f32> = ds.row(42).iter().map(|&v| v * a.sqrt()).collect();
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step,
            class: None,
        };
        let s = gd.golden_subset(&x_t, &ctx);
        assert_eq!(s[0], 42, "exact refine must put the true neighbour first");
    }

    #[test]
    fn golddiff_tracks_optimal_at_low_noise() {
        // Theorem 1 consequence: at low noise, truncation error is
        // negligible, so GoldDiff ≈ Optimal full scan.
        let (ds, sched) = setup();
        let mut gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden);
        let mut opt = super::super::optimal::OptimalDenoiser::new();
        let step = 9;
        let a = sched.alpha_bar(step);
        let x_t: Vec<f32> = ds.row(3).iter().map(|&v| v * a.sqrt()).collect();
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step,
            class: None,
        };
        let f_gd = gd.denoise(&x_t, &ctx).f_hat;
        let f_opt = opt.denoise(&x_t, &ctx).f_hat;
        let err: f32 = f_gd
            .iter()
            .zip(&f_opt)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 1e-3, "max deviation from optimal {err}");
    }

    #[test]
    fn conditional_subset_stays_in_class() {
        let (ds, sched) = setup();
        let mut gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden);
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 5,
            class: Some(3),
        };
        let s = gd.golden_subset(&vec![0.0; ds.d], &ctx);
        assert!(!s.is_empty());
        assert!(s.iter().all(|&i| ds.labels[i as usize] == 3));
    }

    #[test]
    fn all_base_weightings_produce_finite_output() {
        let (ds, sched) = setup();
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 5,
            class: None,
        };
        for base in [
            BaseWeighting::Golden,
            BaseWeighting::PcaSubspace { unbiased: true },
            BaseWeighting::PcaSubspace { unbiased: false },
            BaseWeighting::Kamb,
        ] {
            let mut gd = GoldDiff::paper_defaults(&ds, &sched, base);
            let out = gd.denoise(&vec![0.2; ds.d], &ctx);
            assert!(out.f_hat.iter().all(|v| v.is_finite()), "{base:?}");
            assert!(out.support > 0);
        }
    }

    #[test]
    fn working_set_much_smaller_than_corpus() {
        let (ds, sched) = setup();
        let gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden);
        assert!(gd.working_set_bytes(&ds) < ds.bytes());
    }
}
