//! GoldDiff — Dynamic Time-Aware Golden Subset Diffusion (the paper's
//! contribution, Sec. 3.4), as a plug-and-play wrapper over any base
//! weighting:
//!
//! 1. **Adaptive Coarse Screening** (Eq. 4): top-m_t rows by the s=1/4
//!    downsampled-ℓ2 proxy distance through a pluggable
//!    [`RetrievalBackend`] (flat / batched / cluster-pruned — see
//!    `index::backend`), with m_t *growing* as noise decreases.
//! 2. **Precision Golden Set Selection** (Eq. 5): exact full-resolution
//!    top-k_t inside the candidate pool, with k_t *shrinking* as noise
//!    decreases (Eq. 6).
//! 3. **Unbiased aggregation** (Sec. 3.2): a plain streaming softmax over
//!    the purified support — no weight-flattening tricks needed.
//!
//! `BaseWeighting` selects what Eq. 3's local operator is: plain pixel-space
//! logits (GoldDiff-on-Optimal), the PCA subspace (the paper's primary
//! configuration; `unbiased=false` gives the Tab. 6 WSS ablation arm), or
//! the Kamb patch weighting (Tab. 5). The base denoisers are built once and
//! cached in the `GoldDiff` struct — the seed rebuilt them every step.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use super::kamb::KambDenoiser;
use super::pca::PcaDenoiser;
use super::softmax::{PosteriorStats, StreamingSoftmax};
use super::{descale, sqdist, DenoiseResult, Denoiser, StepContext};
use crate::data::dataset::Dataset;
use crate::data::synthetic::proxy_embed;
use crate::index::backend::{
    BackendOpts, ProxyQuery, RetrievalBackend, RetrievalBackendKind,
};
use crate::schedule::budget::BudgetSchedule;
use crate::schedule::noise::NoiseSchedule;

/// The shared GoldDiff retrieval used by both the CPU reference path and
/// the XLA engine (`coordinator::xla_denoiser`).
///
/// Two regimes, per the paper's Integration→Selection analysis (Sec. 3.3):
///
/// * the **precision fraction** (1−g) of the budget comes from the
///   coarse→fine pipeline — proxy top-m_t then exact top-k (Eqs. 4–5);
/// * the **breadth fraction** g comes from a *stratified* sample of the
///   support (every ⌈n/k⌉-th row with a step-dependent offset; rows are in
///   iid order so this is an unbiased random subset). At high noise the
///   estimator is a Monte-Carlo integrator — "robust to retrieval
///   imprecision but sensitive to sample sparsity" — so nearest-only
///   selection would bias the global mean; the breadth rows restore it.
///
/// As g → 0 this degenerates to pure precision retrieval; as g → 1 to a
/// broad Monte-Carlo subset. Duplicates are skipped, and the fill is
/// guaranteed to return exactly `min(k, support)` distinct rows.
pub fn blended_golden_rows(
    backend: &dyn RetrievalBackend,
    ctx: &StepContext,
    x_t: &[f32],
    m: usize,
    k: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Vec<u32> {
    blended_golden_rows_batch(backend, &[ctx], &[x_t], m, k, h, w, c)
        .pop()
        .unwrap_or_default()
}

/// Batched variant of [`blended_golden_rows`]: one coarse retrieval for the
/// whole group (the engine batches sequences that share a sampling point,
/// so every query shares (m, k, g)), then one batched exact refine over the
/// union of the group's candidate pools, then per-query breadth fill. With
/// the `BatchedScan` backend the group pays a *single* tiled pass over the
/// proxy table and a *single* union scan of the refine candidates.
///
/// All contexts must be at the same sampling point; classes may differ.
pub fn blended_golden_rows_batch(
    backend: &dyn RetrievalBackend,
    ctxs: &[&StepContext],
    xs: &[&[f32]],
    m: usize,
    k: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Vec<Vec<u32>> {
    blended_golden_rows_batch_warm(backend, ctxs, xs, m, k, h, w, c, None)
}

/// [`blended_golden_rows_batch`] with concentration-aware warm-starting.
///
/// Posterior Progressive Concentration says the golden support shrinks
/// monotonically as SNR rises, and adjacent sampling points share most of
/// their high-noise structure (arxiv 2412.09726, 2206.05173) — so the
/// previous sampling point's golden subsets are an excellent candidate pool
/// for this one. When `warm` carries rows recorded at `step − 1`, each
/// query's coarse screen seeds its top-m heap from those rows and then
/// verifies every proxy block against the exact centroid bound
/// `(d(q, c_b) − r_b)² ≥ worst`: blocks that pass provably hold no better
/// row and are skipped outright; blocks that fail are scanned — the
/// "fallback to a full screen" happens per block, so the result is the
/// *identical* top-m row set the cold scan produces (exactness preserved;
/// f32 distance ties remain the only divergence surface, as everywhere in
/// `index`). A query whose eligible seed rows cannot even fill its heap
/// falls back to the cold batched screen entirely.
///
/// Every call records its final golden subsets into `warm` for the next
/// sampling point; the seeds are only ever an accelerator, never a filter,
/// so stale or foreign rows (other sequences in the tick group) are sound.
pub fn blended_golden_rows_batch_warm(
    backend: &dyn RetrievalBackend,
    ctxs: &[&StepContext],
    xs: &[&[f32]],
    m: usize,
    k: usize,
    h: usize,
    w: usize,
    c: usize,
    mut warm: Option<&mut WarmStart>,
) -> Vec<Vec<u32>> {
    assert_eq!(ctxs.len(), xs.len());
    if ctxs.is_empty() {
        return Vec::new();
    }
    debug_assert!(
        ctxs.iter().all(|ctx| ctx.step == ctxs[0].step),
        "a batch group must share one sampling point"
    );
    let ds = ctxs[0].ds;
    let step = ctxs[0].step;
    let g = ctxs[0].sched.g(step) as f64;
    let k_breadth = ((k as f64) * g) as usize;
    let k_precise = k - k_breadth;

    let qs: Vec<Vec<f32>> = xs
        .iter()
        .zip(ctxs)
        .map(|(x, ctx)| descale(x, ctx.alpha_bar()))
        .collect();

    let mut per_query: Vec<Vec<u32>> = if k_precise > 0 {
        let proxies: Vec<Vec<f32>> = qs.iter().map(|q| proxy_embed(q, h, w, c)).collect();
        // the seeded screen is exact, so it may only stand in for a backend
        // whose own screen is exact — over an approximate backend (cluster
        // nprobe > 0) it would *change* results, not just accelerate them
        let seeds: Option<Vec<u32>> = if backend.is_exact() {
            warm.as_ref()
                .and_then(|w| w.seed_for(step))
                .map(<[u32]>::to_vec)
        } else {
            None
        };
        let cands = match seeds {
            Some(seed_rows) if !seed_rows.is_empty() => warm_top_m_batch(
                backend,
                ds,
                &proxies,
                ctxs,
                m,
                &seed_rows,
                warm.as_deref_mut(),
            ),
            _ => {
                let queries: Vec<ProxyQuery> = proxies
                    .iter()
                    .zip(ctxs)
                    .map(|(p, ctx)| ProxyQuery {
                        proxy: p,
                        class: ctx.class,
                    })
                    .collect();
                backend.top_m_batch(ds, &queries, m)
            }
        };
        // the batched refine ladder: one scan of the group's candidate-pool
        // union per tick, each full-resolution row loaded once and scored
        // against every query whose pool holds it, one bounded heap per
        // query (the trait default degrades to per-query refines)
        let qrefs: Vec<&[f32]> = qs.iter().map(|q| q.as_slice()).collect();
        let pools: Vec<&[u32]> = cands.iter().map(|p| p.as_slice()).collect();
        backend.refine_top_k_batch(ds, &qrefs, &pools, k_precise)
    } else {
        vec![Vec::new(); xs.len()]
    };

    for (rows, ctx) in per_query.iter_mut().zip(ctxs) {
        breadth_fill(ctx, rows, k, k_breadth);
    }
    if let Some(w) = warm {
        w.record(step, &per_query);
    }
    per_query
}

/// The subset-reuse corrector screen (the few-step tentpole's perf move).
///
/// A higher-order solver's second score evaluation sits a fraction of a
/// step past the predictor tick, on the predictor's own provisional state —
/// by Posterior Progressive Concentration its golden subset is (almost
/// always) a subset of the predictor's candidate pool. So instead of paying
/// a second coarse screen, the corrector re-runs **only the masked refine**
/// over `pool` — the sorted union of the predictor tick's golden subsets —
/// then the usual per-query breadth fill. Returns the subsets plus whether
/// the reuse actually engaged.
///
/// Exactness discipline (same gate as the warm-start screen): the reuse
/// stands down to a full cold screen + refine when
///
/// * the backend is approximate (`!is_exact()`) — a pool-restricted refine
///   over it would *change* results, not just accelerate them, or
/// * the pool cannot even cover `k_precise` — a refine over it could not
///   return enough precision rows.
///
/// Within the pool the refine is the backend's own exact full-resolution
/// top-k, so the only divergence surface vs a fresh screen is a true
/// neighbour that left the predictor's top-m pool *within* the fractional
/// step — second-order-small by the same concentration argument, and the
/// corrector's output only steers the *average* slope of the update.
/// Nothing is recorded into warm-start state: the predictor's own record
/// already seeds the next placed point's screen.
pub fn corrector_golden_rows_batch(
    backend: &dyn RetrievalBackend,
    ctxs: &[&StepContext],
    xs: &[&[f32]],
    pool: &[u32],
    m: usize,
    k: usize,
    h: usize,
    w: usize,
    c: usize,
) -> (Vec<Vec<u32>>, bool) {
    assert_eq!(ctxs.len(), xs.len());
    if ctxs.is_empty() {
        return (Vec::new(), false);
    }
    debug_assert!(
        pool.windows(2).all(|p| p[0] < p[1]),
        "corrector pool must be sorted distinct row ids"
    );
    let ds = ctxs[0].ds;
    let step = ctxs[0].step;
    let g = ctxs[0].sched.g(step) as f64;
    let k_breadth = ((k as f64) * g) as usize;
    let k_precise = k - k_breadth;
    // class-conditional queries may only refine class rows: restrict the
    // shared pool per query (the group union can mix classes)
    let class_pools: Vec<Option<Vec<u32>>> = ctxs
        .iter()
        .map(|ctx| {
            ctx.class.map(|y| {
                pool.iter()
                    .copied()
                    .filter(|&r| ds.labels[r as usize] == y)
                    .collect::<Vec<u32>>()
            })
        })
        .collect();
    let pools: Vec<&[u32]> = class_pools
        .iter()
        .map(|p| p.as_deref().unwrap_or(pool))
        .collect();
    let reusable = k_precise > 0
        && backend.is_exact()
        && pools.iter().all(|p| p.len() >= k_precise);
    if k_precise > 0 && !reusable {
        // cold full screen (no warm seeding or recording — the corrector
        // must leave cross-step warm state exactly as the predictor set it)
        return (
            blended_golden_rows_batch(backend, ctxs, xs, m, k, h, w, c),
            false,
        );
    }
    let mut per_query: Vec<Vec<u32>> = if k_precise > 0 {
        let qs: Vec<Vec<f32>> = xs
            .iter()
            .zip(ctxs)
            .map(|(x, ctx)| descale(x, ctx.alpha_bar()))
            .collect();
        let qrefs: Vec<&[f32]> = qs.iter().map(|q| q.as_slice()).collect();
        backend.refine_top_k_batch(ds, &qrefs, &pools, k_precise)
    } else {
        vec![Vec::new(); xs.len()]
    };
    for (rows, ctx) in per_query.iter_mut().zip(ctxs) {
        breadth_fill(ctx, rows, k, k_breadth);
    }
    (per_query, k_precise > 0)
}

/// Cross-timestep warm-start state: golden-subset unions keyed by sampling
/// point, plus engagement telemetry. Owned by whoever drives a trajectory
/// (`GoldDiff` on the CPU path, `XlaDenoiser` in the engine); sound to
/// share across the sequences of a tick group since seeds never filter.
#[derive(Debug, Default)]
pub struct WarmStart {
    /// step → sorted distinct union of that step's golden subsets (latest
    /// tick group wins; continuous batching keeps one entry per live step)
    prev: HashMap<usize, Vec<u32>>,
    /// queries served by the seeded screen
    pub hits: u64,
    /// queries that fell back to the cold screen (insufficient seeds)
    pub fallbacks: u64,
}

impl WarmStart {
    pub fn new() -> WarmStart {
        WarmStart::default()
    }

    /// Seed rows for a screen at `step` — the union recorded at the latest
    /// earlier sampling point. Under the full grid that is exactly
    /// `step − 1`; under a budgeted step plan (`schedule::steps`) the
    /// trajectory jumps placed point to placed point, so the latest record
    /// may sit several grid points back — still sound (seeds accelerate,
    /// never filter) and still the freshest support available.
    pub fn seed_for(&self, step: usize) -> Option<&[u32]> {
        self.prev
            .iter()
            .filter(|(&s, _)| s < step)
            .max_by_key(|&(&s, _)| s)
            .map(|(_, v)| v.as_slice())
    }

    /// Record a tick group's golden subsets for the next sampling point.
    pub fn record(&mut self, step: usize, subsets: &[Vec<u32>]) {
        let mut union: Vec<u32> = subsets.iter().flatten().copied().collect();
        union.sort_unstable();
        union.dedup();
        self.prev.insert(step, union);
    }
}

/// The seeded exact screen: per query, fill the top-m heap from the seed
/// rows, then run the backend's [`RetrievalBackend::warm_top_m`] sweep —
/// the global nearest-block sweep by default, or the shard-local sweep
/// (with whole-shard bound skips) over a sharded backend. Queries whose
/// eligible seeds cannot fill the heap are batched through the backend's
/// cold screen instead.
fn warm_top_m_batch(
    backend: &dyn RetrievalBackend,
    ds: &Dataset,
    proxies: &[Vec<f32>],
    ctxs: &[&StepContext],
    m: usize,
    seeds: &[u32],
    warm: Option<&mut WarmStart>,
) -> Vec<Vec<u32>> {
    let mut out: Vec<Option<Vec<u32>>> = proxies
        .iter()
        .zip(ctxs)
        .map(|(qp, ctx)| backend.warm_top_m(ds, qp, ctx.class, m, seeds))
        .collect();
    let cold_idx: Vec<usize> = (0..out.len()).filter(|&i| out[i].is_none()).collect();
    if !cold_idx.is_empty() {
        let queries: Vec<ProxyQuery> = cold_idx
            .iter()
            .map(|&i| ProxyQuery {
                proxy: &proxies[i],
                class: ctxs[i].class,
            })
            .collect();
        let cold = backend.top_m_batch(ds, &queries, m);
        for (&i, rows) in cold_idx.iter().zip(cold) {
            out[i] = Some(rows);
        }
    }
    if let Some(w) = warm {
        w.fallbacks += cold_idx.len() as u64;
        w.hits += (out.len() - cold_idx.len()) as u64;
    }
    out.into_iter().map(|rows| rows.unwrap_or_default()).collect()
}

/// Stratified breadth fill over the (class-restricted) support.
///
/// Invariant: on return `rows` holds exactly `min(k, support_size)`
/// distinct rows (the precise picks are always support members, so the
/// target clamps to what is achievable — strides colliding near `n` fall
/// through to the sequential top-up, which covers the whole support).
fn breadth_fill(ctx: &StepContext, rows: &mut Vec<u32>, k: usize, k_breadth: usize) {
    if k_breadth == 0 {
        return;
    }
    let support: &[u32] = match ctx.class {
        Some(y) => &ctx.ds.class_rows[y as usize],
        None => &[],
    };
    let n = if ctx.class.is_some() {
        support.len()
    } else {
        ctx.ds.n
    };
    let target = k.min(n);
    let row_at = |idx: usize| -> u32 {
        if ctx.class.is_some() {
            support[idx]
        } else {
            idx as u32
        }
    };
    let mut seen: HashSet<u32> = rows.iter().copied().collect();
    let stride = (n as f64 / k_breadth.max(1) as f64).max(1.0);
    let offset = (ctx.step as f64 * 0.618_033_99).fract() * stride;
    let mut pos = offset;
    while rows.len() < target && (pos as usize) < n {
        let gid = row_at(pos as usize);
        if seen.insert(gid) {
            rows.push(gid);
        }
        pos += stride;
    }
    // top up sequentially if strides collided with precise picks or with
    // each other near n
    let mut idx = 0usize;
    while rows.len() < target && idx < n {
        let gid = row_at(idx);
        if seen.insert(gid) {
            rows.push(gid);
        }
        idx += 1;
    }
    debug_assert_eq!(rows.len(), target, "breadth fill must reach its target");
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaseWeighting {
    /// pixel-space Gaussian-kernel logits + unbiased SS
    Golden,
    /// PCA-subspace logits; `unbiased=false` = biased WSS (ablation)
    PcaSubspace { unbiased: bool },
    /// Kamb patch-based weighting restricted to the golden subset
    Kamb,
}

pub struct GoldDiff {
    pub base: BaseWeighting,
    pub budget: BudgetSchedule,
    /// pluggable coarse-retrieval backend (shared with the engine)
    pub backend: Arc<dyn RetrievalBackend>,
    /// concentration-aware warm-starting of the coarse screen (exact; off
    /// by default on the CPU path — single trajectories rarely carry
    /// enough seed mass, the engine's tick groups are where it pays)
    pub warm_start: bool,
    warm: WarmStart,
    h: usize,
    w: usize,
    c: usize,
    /// cached base denoisers — built once per GoldDiff, not per step
    pca: Option<PcaDenoiser>,
    kamb: Option<KambDenoiser>,
    /// last step's budgets (telemetry)
    pub last_m: usize,
    pub last_k: usize,
    /// sampling points `0..gauss_switch` are served by the closed-form
    /// Gaussian tier (`denoiser::gaussian`) with zero screens and zero
    /// refines — 0 disables the tier. Stands down to full retrieval when
    /// the dataset carries no moment tier (streamed legacy store, or a
    /// corrupt `gauss_*` section pinned degraded at open).
    pub gauss_switch: usize,
    /// bound-driven per-class switch: when set, the switch point is derived
    /// from the class moment spread at this error tolerance instead of the
    /// fixed `gauss_switch` (tighter classes hand off later)
    pub gauss_tol: Option<f64>,
    /// ticks served by the Gaussian tier (telemetry)
    pub gauss_ticks: u64,
    /// the last predictor tick's golden-subset union (sorted distinct),
    /// offered to the next corrector eval then consumed
    reuse_pool: Vec<u32>,
    /// higher-order corrector evals served through retrieval (telemetry)
    pub corrector_refines: u64,
    /// corrector evals that reused the predictor's pool — refine only,
    /// no coarse screen (telemetry)
    pub screens_reused: u64,
}

impl GoldDiff {
    /// Paper defaults: m_min = k_max = N/10, m_max = N/4, k_min = N/20
    /// (Sec. 4.1), with the bucket ladder left un-padded on this CPU path
    /// (the XLA engine buckets via the manifest).
    pub fn paper_defaults(ds: &Dataset, _sched: &NoiseSchedule, base: BaseWeighting) -> GoldDiff {
        let buckets: Vec<usize> = (5..=17).map(|p| 1usize << p).collect();
        GoldDiff::new(ds, BudgetSchedule::paper_defaults(ds.n, &buckets), base)
    }

    pub fn new(ds: &Dataset, budget: BudgetSchedule, base: BaseWeighting) -> GoldDiff {
        let pca = match base {
            BaseWeighting::PcaSubspace { unbiased } => Some(PcaDenoiser::new(ds, unbiased)),
            _ => None,
        };
        let kamb = match base {
            BaseWeighting::Kamb => Some(KambDenoiser::new(ds)),
            _ => None,
        };
        // the GOLDDIFF_KERNEL env leg (CI scalar matrix) flips the default
        // backend to the row-major reference paths; GOLDDIFF_SHARDS routes
        // it through the shard-parallel merge layer (tier1-sharded leg)
        let kernel = crate::config::env_flag("GOLDDIFF_KERNEL", true);
        let opts = BackendOpts {
            kernel,
            refine_kernel: kernel,
            quant: crate::config::env_flag("GOLDDIFF_QUANT", false),
            simd: crate::config::env_flag("GOLDDIFF_SIMD", true),
            shards: crate::config::env_usize("GOLDDIFF_SHARDS", 1),
            ..BackendOpts::default()
        };
        let backend: Arc<dyn RetrievalBackend> = RetrievalBackendKind::Flat.build(ds, opts);
        GoldDiff {
            base,
            budget,
            backend,
            warm_start: false,
            warm: WarmStart::new(),
            h: ds.h,
            w: ds.w,
            c: ds.c,
            pca,
            kamb,
            last_m: 0,
            last_k: 0,
            gauss_switch: 0,
            gauss_tol: None,
            gauss_ticks: 0,
            reuse_pool: Vec::new(),
            corrector_refines: 0,
            screens_reused: 0,
        }
    }

    /// Swap the coarse-retrieval backend (the engine shares one per dataset).
    pub fn with_backend(mut self, backend: Arc<dyn RetrievalBackend>) -> Self {
        self.backend = backend;
        self
    }

    /// Toggle the concentration warm-start (exactness is preserved either
    /// way — see [`blended_golden_rows_batch_warm`]).
    pub fn with_warm_start(mut self, on: bool) -> Self {
        self.warm_start = on;
        self
    }

    /// Warm-start engagement telemetry: (seeded screens, cold fallbacks).
    pub fn warm_counts(&self) -> (u64, u64) {
        (self.warm.hits, self.warm.fallbacks)
    }

    /// Serve the first `switch` sampling points from the Gaussian moment
    /// tier (0 = off). The retrieval segment from `switch` onward is
    /// untouched — Gaussian ticks never consult the backend, so budgets,
    /// warm-start state, and golden subsets are byte-identical to a run
    /// that entered at `switch` directly.
    pub fn with_gauss(mut self, switch: usize) -> Self {
        self.gauss_switch = switch;
        self
    }

    /// Bound-driven per-class Gaussian switching: each tick resolves its
    /// own switch point from the error bound `err(i) = s̄/(s̄ + σ_i²)` at
    /// this tolerance, with `s̄` the *class* moment spread for conditional
    /// contexts (`GaussMoments::spread_for`) — tighter classes hand off
    /// later. Overrides any fixed `with_gauss` prefix.
    pub fn with_gauss_auto(mut self, tol: f64) -> Self {
        self.gauss_tol = Some(tol);
        self
    }

    /// Whether this tick falls in its Gaussian prefix AND the dataset's
    /// moment tier is available to serve it. With `gauss_tol` set the
    /// prefix is resolved per class from the bound; otherwise the fixed
    /// `gauss_switch` applies to every class.
    fn gauss_serves<'a>(
        &self,
        ctx: &StepContext<'a>,
    ) -> Option<&'a crate::data::gauss::GaussMoments> {
        match self.gauss_tol {
            // fixed prefix: never touch the (lazily built) moment tier
            // unless the tier is actually on
            None if ctx.step < self.gauss_switch => ctx.ds.gauss_moments(),
            None => None,
            Some(tol) => {
                let gm = ctx.ds.gauss_moments()?;
                let switch = super::gaussian::resolve_switch_for(
                    super::gaussian::GaussSwitch::Auto,
                    ctx.sched,
                    gm,
                    tol,
                    ctx.class,
                );
                (ctx.step < switch).then_some(gm)
            }
        }
    }

    /// The coarse→fine retrieval: returns the golden subset S_t (row ids,
    /// nearest-first) for a query at sampling point `step`.
    pub fn golden_subset(&mut self, x_t: &[f32], ctx: &StepContext) -> Vec<u32> {
        self.golden_subsets(&[x_t], &[ctx]).pop().unwrap_or_default()
    }

    /// Batched retrieval for a group of sequences sharing one sampling
    /// point: one coarse pass over the proxy table (with the batched
    /// backend) instead of one per sequence.
    pub fn golden_subsets(&mut self, xs: &[&[f32]], ctxs: &[&StepContext]) -> Vec<Vec<u32>> {
        if ctxs.is_empty() {
            return Vec::new();
        }
        let b = self.budget.at(ctxs[0].sched, ctxs[0].step);
        self.last_m = b.m;
        self.last_k = b.k;
        let warm = self.warm_start.then_some(&mut self.warm);
        let per_query = blended_golden_rows_batch_warm(
            self.backend.as_ref(),
            ctxs,
            xs,
            b.m,
            b.k,
            self.h,
            self.w,
            self.c,
            warm,
        );
        // stash this tick's golden-subset union for a higher-order
        // solver's corrector eval (consumed by `corrector_denoise`)
        let mut pool: Vec<u32> = per_query.iter().flatten().copied().collect();
        pool.sort_unstable();
        pool.dedup();
        self.reuse_pool = pool;
        per_query
    }

    /// The base-weighting aggregation over one golden subset — shared by
    /// `denoise` and `corrector_denoise` (byte-identical math either way).
    fn aggregate(&mut self, golden: Vec<u32>, x_t: &[f32], ctx: &StepContext) -> DenoiseResult {
        let support = golden.len();
        let ds = ctx.ds;
        match self.base {
            BaseWeighting::Golden => {
                let q = descale(x_t, ctx.alpha_bar());
                let scale = ctx.logit_scale();
                // golden rows stream through the source in subset order —
                // identical pushes to the resident gather, so the softmax
                // aggregate is bit-identical on a streamed corpus
                let mut acc = StreamingSoftmax::new(ds.d);
                ds.visit_rows(golden.iter().copied(), |_, row| {
                    acc.push(-sqdist(&q, row) * scale, row);
                });
                let (f_hat, stats): (Vec<f32>, PosteriorStats) = acc.finish();
                DenoiseResult {
                    f_hat,
                    stats,
                    support,
                }
            }
            BaseWeighting::PcaSubspace { .. } => {
                let base = self.pca.as_mut().expect("pca base cached at construction");
                base.subset = Some(golden);
                let mut out = base.denoise(x_t, ctx);
                out.support = support;
                out
            }
            BaseWeighting::Kamb => {
                let base = self
                    .kamb
                    .as_mut()
                    .expect("kamb base cached at construction");
                base.subset = Some(golden);
                let mut out = base.denoise(x_t, ctx);
                out.support = support;
                out
            }
        }
    }
}

impl Denoiser for GoldDiff {
    fn name(&self) -> String {
        match self.base {
            BaseWeighting::Golden => "golddiff".into(),
            BaseWeighting::PcaSubspace { unbiased: true } => "golddiff-pca".into(),
            BaseWeighting::PcaSubspace { unbiased: false } => "golddiff-wss".into(),
            BaseWeighting::Kamb => "golddiff-kamb".into(),
        }
    }

    fn denoise(&mut self, x_t: &[f32], ctx: &StepContext) -> DenoiseResult {
        // high-noise fast path: ticks inside the Gaussian prefix are
        // closed-form — zero screens, zero refines, zero support
        if let Some(gm) = self.gauss_serves(ctx) {
            self.gauss_ticks += 1;
            return super::gaussian::gauss_result(gm, x_t, ctx.alpha_bar(), ctx.class);
        }
        let golden = self.golden_subset(x_t, ctx);
        self.aggregate(golden, x_t, ctx)
    }

    fn corrector_denoise(&mut self, x_t: &[f32], ctx: &StepContext) -> DenoiseResult {
        // the solver coasts first-order through Gaussian ticks
        // (support == 0), so a corrector eval can only land in the
        // retrieval segment — keep the guard anyway for direct callers
        if let Some(gm) = self.gauss_serves(ctx) {
            self.gauss_ticks += 1;
            return super::gaussian::gauss_result(gm, x_t, ctx.alpha_bar(), ctx.class);
        }
        let b = self.budget.at(ctx.sched, ctx.step);
        self.last_m = b.m;
        self.last_k = b.k;
        // consume the predictor tick's pool: a stale pool must never
        // serve a second corrector (mem::take leaves it empty → fallback)
        let pool = std::mem::take(&mut self.reuse_pool);
        let (mut subsets, reused) = corrector_golden_rows_batch(
            self.backend.as_ref(),
            &[ctx],
            &[x_t],
            &pool,
            b.m,
            b.k,
            self.h,
            self.w,
            self.c,
        );
        self.corrector_refines += 1;
        if reused {
            self.screens_reused += 1;
        }
        let golden = subsets.pop().unwrap_or_default();
        self.aggregate(golden, x_t, ctx)
    }

    fn working_set_bytes(&self, ds: &Dataset) -> u64 {
        // proxy table + gathered golden subset + scratch — NOT the corpus
        // resident per-query working set (the corpus itself is shared,
        // dominant term is the m_max gather)
        (ds.n * ds.proxy_d + self.budget.m_max * ds.d + 4 * ds.d) as u64 * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;
    use crate::index::backend::{BatchedScan, FlatScan};
    use crate::schedule::noise::ScheduleKind;

    fn setup() -> (Dataset, NoiseSchedule) {
        let mut spec = preset("cifar-sim").unwrap().clone();
        spec.n = 500;
        (
            Dataset::synthesize(&spec, 6),
            NoiseSchedule::new(ScheduleKind::DdpmLinear, 10),
        )
    }

    #[test]
    fn golden_subset_sizes_follow_schedule() {
        let (ds, sched) = setup();
        let mut gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden);
        let x = vec![0.1f32; ds.d];
        let ctx0 = StepContext {
            ds: &ds,
            sched: &sched,
            step: 0,
            class: None,
        };
        let s0 = gd.golden_subset(&x, &ctx0);
        let (m0, k0) = (gd.last_m, gd.last_k);
        let ctx9 = StepContext {
            ds: &ds,
            sched: &sched,
            step: 9,
            class: None,
        };
        let s9 = gd.golden_subset(&x, &ctx9);
        let (m9, k9) = (gd.last_m, gd.last_k);
        assert_eq!(s0.len(), k0);
        assert_eq!(s9.len(), k9);
        assert!(m9 > m0, "retrieval scope must grow: {m0} -> {m9}");
        assert!(k9 < k0, "aggregation budget must shrink: {k0} -> {k9}");
    }

    #[test]
    fn low_noise_golden_subset_contains_true_neighbour() {
        let (ds, sched) = setup();
        let mut gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden);
        let step = 9;
        let a = sched.alpha_bar(step);
        let x_t: Vec<f32> = ds.row(42).iter().map(|&v| v * a.sqrt()).collect();
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step,
            class: None,
        };
        let s = gd.golden_subset(&x_t, &ctx);
        assert_eq!(s[0], 42, "exact refine must put the true neighbour first");
    }

    #[test]
    fn golddiff_tracks_optimal_at_low_noise() {
        // Theorem 1 consequence: at low noise, truncation error is
        // negligible, so GoldDiff ≈ Optimal full scan.
        let (ds, sched) = setup();
        let mut gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden);
        let mut opt = super::super::optimal::OptimalDenoiser::new();
        let step = 9;
        let a = sched.alpha_bar(step);
        let x_t: Vec<f32> = ds.row(3).iter().map(|&v| v * a.sqrt()).collect();
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step,
            class: None,
        };
        let f_gd = gd.denoise(&x_t, &ctx).f_hat;
        let f_opt = opt.denoise(&x_t, &ctx).f_hat;
        let err: f32 = f_gd
            .iter()
            .zip(&f_opt)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 1e-3, "max deviation from optimal {err}");
    }

    #[test]
    fn conditional_subset_stays_in_class() {
        let (ds, sched) = setup();
        let mut gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden);
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 5,
            class: Some(3),
        };
        let s = gd.golden_subset(&vec![0.0; ds.d], &ctx);
        assert!(!s.is_empty());
        assert!(s.iter().all(|&i| ds.labels[i as usize] == 3));
    }

    #[test]
    fn all_base_weightings_produce_finite_output() {
        let (ds, sched) = setup();
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 5,
            class: None,
        };
        for base in [
            BaseWeighting::Golden,
            BaseWeighting::PcaSubspace { unbiased: true },
            BaseWeighting::PcaSubspace { unbiased: false },
            BaseWeighting::Kamb,
        ] {
            let mut gd = GoldDiff::paper_defaults(&ds, &sched, base);
            let out = gd.denoise(&vec![0.2; ds.d], &ctx);
            assert!(out.f_hat.iter().all(|v| v.is_finite()), "{base:?}");
            assert!(out.support > 0);
        }
    }

    #[test]
    fn cached_base_denoiser_is_reused_across_steps() {
        // the seed rebuilt PcaDenoiser/KambDenoiser on every denoise call;
        // the cached instances must keep producing identical output
        let (ds, sched) = setup();
        let mut gd = GoldDiff::paper_defaults(
            &ds,
            &sched,
            BaseWeighting::PcaSubspace { unbiased: true },
        );
        let x = vec![0.15f32; ds.d];
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 4,
            class: None,
        };
        let a = gd.denoise(&x, &ctx).f_hat;
        let b = gd.denoise(&x, &ctx).f_hat;
        assert_eq!(a, b, "cached base must be deterministic across calls");
        assert!(gd.pca.is_some() && gd.kamb.is_none());
    }

    #[test]
    fn breadth_fill_returns_exactly_k_distinct_rows_at_tiny_n() {
        // regression (satellite): strides colliding near n must fall back
        // to the sequential top-up so exactly min(k, n) rows return
        let mut spec = preset("cifar-sim").unwrap().clone();
        spec.n = 24;
        let ds = Dataset::synthesize(&spec, 17);
        let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
        let backend = FlatScan::new(1);
        let x = vec![0.2f32; ds.d];
        // step 0 = deepest noise: g ≈ 1, the fill is breadth-dominated
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 0,
            class: None,
        };
        for k in [1usize, 7, 23, 24, 40] {
            let rows = blended_golden_rows(&backend, &ctx, &x, 6, k, ds.h, ds.w, ds.c);
            let want = k.min(ds.n);
            assert_eq!(rows.len(), want, "k={k}");
            let distinct: HashSet<u32> = rows.iter().copied().collect();
            assert_eq!(distinct.len(), want, "k={k} duplicates");
            assert!(rows.iter().all(|&r| (r as usize) < ds.n));
        }
    }

    #[test]
    fn breadth_fill_conditional_clamps_to_class_support() {
        let mut spec = preset("cifar-sim").unwrap().clone();
        spec.n = 40;
        let ds = Dataset::synthesize(&spec, 19);
        let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
        let backend = FlatScan::new(1);
        let x = vec![0.1f32; ds.d];
        // pick the best-populated class (tiny n can leave classes empty)
        let class = (0..ds.classes)
            .max_by_key(|&c| ds.class_rows[c].len())
            .unwrap() as u32;
        let support = ds.class_rows[class as usize].len();
        assert!(support > 0);
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 0,
            class: Some(class),
        };
        let rows = blended_golden_rows(&backend, &ctx, &x, 8, support + 10, ds.h, ds.w, ds.c);
        assert_eq!(rows.len(), support, "cannot exceed the class support");
        assert!(rows.iter().all(|&r| ds.labels[r as usize] == class));
    }

    #[test]
    fn batched_subsets_match_single_query_subsets() {
        let (ds, sched) = setup();
        let mut gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden)
            .with_backend(Arc::new(BatchedScan::new(2)));
        let xs_data: Vec<Vec<f32>> = (0..6)
            .map(|i| {
                let mut rng = crate::util::rng::Pcg64::new(100 + i);
                (0..ds.d).map(|_| rng.normal()).collect()
            })
            .collect();
        for step in [0usize, 5, 9] {
            let ctx = StepContext {
                ds: &ds,
                sched: &sched,
                step,
                class: None,
            };
            let xs: Vec<&[f32]> = xs_data.iter().map(|x| x.as_slice()).collect();
            let ctxs: Vec<&StepContext> = xs.iter().map(|_| &ctx).collect();
            let batch = gd.golden_subsets(&xs, &ctxs);
            for (i, x) in xs.iter().enumerate() {
                let solo = gd.golden_subset(x, &ctx);
                assert_eq!(batch[i], solo, "step {step} seq {i}");
            }
        }
    }

    #[test]
    fn warm_start_matches_cold_across_a_group_trajectory() {
        // exactness: a tick group stepped 0..steps with warm-starting on
        // must produce byte-identical golden subsets to the cold run, and
        // the seeded screen must actually engage somewhere along the way
        let (ds, sched) = setup();
        let xs_data: Vec<Vec<f32>> = (0..8)
            .map(|i| {
                let mut rng = crate::util::rng::Pcg64::new(300 + i);
                (0..ds.d).map(|_| rng.normal()).collect()
            })
            .collect();
        let run = |warm_on: bool| -> (Vec<Vec<Vec<u32>>>, (u64, u64)) {
            let mut gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden)
                .with_backend(Arc::new(BatchedScan::new(2)))
                .with_warm_start(warm_on);
            let mut all = Vec::new();
            for step in 0..sched.steps {
                let ctx = StepContext {
                    ds: &ds,
                    sched: &sched,
                    step,
                    class: None,
                };
                let xs: Vec<&[f32]> = xs_data.iter().map(|x| x.as_slice()).collect();
                let ctxs: Vec<&StepContext> = xs.iter().map(|_| &ctx).collect();
                all.push(gd.golden_subsets(&xs, &ctxs));
            }
            (all, gd.warm_counts())
        };
        let (cold, cold_counts) = run(false);
        let (warm, warm_counts) = run(true);
        assert_eq!(cold, warm, "warm-starting must never change the subsets");
        assert_eq!(cold_counts, (0, 0), "cold run must never consult seeds");
        assert!(
            warm_counts.0 + warm_counts.1 > 0,
            "warm run must at least attempt seeded screens"
        );
    }

    #[test]
    fn warm_screen_engages_when_group_seeds_cover_the_budget() {
        // an explicit seed pool ≥ m: the seeded screen must serve the query
        // without falling back AND return the exact cold top-m
        let (ds, sched) = setup();
        let backend = BatchedScan::new(1);
        let mut warm = WarmStart::new();
        let step = sched.steps - 1; // largest m of the trajectory
        let b = crate::schedule::budget::BudgetSchedule::paper_defaults(
            ds.n,
            &[1usize << 17],
        )
        .at(&sched, step);
        // seed with every row id — trivially sufficient and sound
        warm.record(step - 1, &[(0..ds.n as u32).collect::<Vec<u32>>()]);
        let mut rng = crate::util::rng::Pcg64::new(17);
        let x: Vec<f32> = (0..ds.d).map(|_| rng.normal()).collect();
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step,
            class: None,
        };
        let warm_rows = blended_golden_rows_batch_warm(
            &backend,
            &[&ctx],
            &[x.as_slice()],
            b.m,
            b.k,
            ds.h,
            ds.w,
            ds.c,
            Some(&mut warm),
        );
        assert_eq!(warm.hits, 1, "full-corpus seeds must serve the screen");
        assert_eq!(warm.fallbacks, 0);
        let cold_rows = blended_golden_rows(&backend, &ctx, &x, b.m, b.k, ds.h, ds.w, ds.c);
        assert_eq!(warm_rows[0], cold_rows);
        // the recorder replaced this step's entry for the next tick
        assert!(warm.seed_for(step + 1).is_some());
    }

    #[test]
    fn warm_screen_falls_back_on_insufficient_or_missing_seeds() {
        let (ds, sched) = setup();
        let backend = BatchedScan::new(1);
        let mut warm = WarmStart::new();
        warm.record(4, &[vec![1, 2, 3]]); // far too few for m
        let x = vec![0.1f32; ds.d];
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 5,
            class: None,
        };
        let rows = blended_golden_rows_batch_warm(
            &backend,
            &[&ctx],
            &[x.as_slice()],
            ds.n / 4,
            ds.n / 20,
            ds.h,
            ds.w,
            ds.c,
            Some(&mut warm),
        );
        assert_eq!(warm.fallbacks, 1, "3 seeds cannot fill an m = n/4 heap");
        assert_eq!(
            rows[0],
            blended_golden_rows(&backend, &ctx, &x, ds.n / 4, ds.n / 20, ds.h, ds.w, ds.c)
        );
        // no entry for the requested step at all → cold path, no counters
        let mut fresh = WarmStart::new();
        let _ = blended_golden_rows_batch_warm(
            &backend,
            &[&ctx],
            &[x.as_slice()],
            8,
            4,
            ds.h,
            ds.w,
            ds.c,
            Some(&mut fresh),
        );
        assert_eq!((fresh.hits, fresh.fallbacks), (0, 0));
    }

    #[test]
    fn warm_screen_never_engages_over_an_approximate_backend() {
        // cluster with nprobe > 0 is approximate: the exact seeded screen
        // would CHANGE its results, so the warm path must stand down and
        // the output must equal the backend's own (cold) screen
        let (ds, sched) = setup();
        let approx = crate::index::backend::ClusterPruned::build_with_threads(&ds, 12, 2, 3, 1);
        assert!(!approx.is_exact());
        let mut warm = WarmStart::new();
        warm.record(8, &[(0..ds.n as u32).collect::<Vec<u32>>()]);
        let x = vec![0.1f32; ds.d];
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 9,
            class: None,
        };
        let rows = blended_golden_rows_batch_warm(
            &approx,
            &[&ctx],
            &[x.as_slice()],
            ds.n / 4,
            ds.n / 20,
            ds.h,
            ds.w,
            ds.c,
            Some(&mut warm),
        );
        assert_eq!((warm.hits, warm.fallbacks), (0, 0), "warm must stand down");
        let cold = blended_golden_rows(&approx, &ctx, &x, ds.n / 4, ds.n / 20, ds.h, ds.w, ds.c);
        assert_eq!(rows[0], cold);
        // exact backends still pass the gate
        assert!(BatchedScan::new(1).is_exact());
        assert!(crate::index::backend::ClusterPruned::build_with_threads(&ds, 12, 0, 3, 1)
            .is_exact());
    }

    #[test]
    fn warm_screen_respects_class_restrictions() {
        let (ds, sched) = setup();
        let class = (0..ds.classes)
            .max_by_key(|&c| ds.class_rows[c].len())
            .unwrap() as u32;
        let support = ds.class_rows[class as usize].len();
        let m = (support / 2).max(1);
        let mut warm = WarmStart::new();
        warm.record(8, &[(0..ds.n as u32).collect::<Vec<u32>>()]);
        let backend = BatchedScan::new(1);
        let x = vec![0.05f32; ds.d];
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 9,
            class: Some(class),
        };
        let rows = blended_golden_rows_batch_warm(
            &backend,
            &[&ctx],
            &[x.as_slice()],
            m,
            m.min(4).max(1),
            ds.h,
            ds.w,
            ds.c,
            Some(&mut warm),
        );
        assert!(rows[0].iter().all(|&r| ds.labels[r as usize] == class));
    }

    #[test]
    fn corrector_refine_over_a_covering_pool_is_the_exact_pool_top_k() {
        // subset reuse must be the backend's own exact refine over the
        // pool: the precise prefix equals a brute-force full-resolution
        // top-k_precise, and the breadth fill tops up to exactly k
        let (ds, sched) = setup();
        let backend = BatchedScan::new(1);
        let step = 8;
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step,
            class: None,
        };
        let mut rng = crate::util::rng::Pcg64::new(23);
        let x: Vec<f32> = (0..ds.d).map(|_| rng.normal()).collect();
        let (m, k) = (ds.n / 4, ds.n / 10);
        let pool: Vec<u32> = (0..ds.n as u32).collect();
        let (rows, reused) = corrector_golden_rows_batch(
            &backend,
            &[&ctx],
            &[x.as_slice()],
            &pool,
            m,
            k,
            ds.h,
            ds.w,
            ds.c,
        );
        assert!(reused, "an exact backend + covering pool must reuse");
        let g = sched.g(step) as f64;
        let k_precise = k - ((k as f64) * g) as usize;
        assert!(k_precise > 0, "low-noise step must want precision rows");
        assert_eq!(rows[0].len(), k);
        let distinct: HashSet<u32> = rows[0].iter().copied().collect();
        assert_eq!(distinct.len(), k);
        let q = descale(&x, ctx.alpha_bar());
        let mut scored: Vec<(f32, u32)> = pool
            .iter()
            .map(|&r| (sqdist(&q, ds.row(r as usize)), r))
            .collect();
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let want: Vec<u32> = scored[..k_precise].iter().map(|&(_, r)| r).collect();
        assert_eq!(&rows[0][..k_precise], &want[..]);
    }

    #[test]
    fn corrector_falls_back_without_a_usable_pool() {
        let (ds, sched) = setup();
        let backend = BatchedScan::new(1);
        let step = 7;
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step,
            class: None,
        };
        let x = vec![0.12f32; ds.d];
        let (m, k) = (ds.n / 4, ds.n / 10);
        let cold = blended_golden_rows(&backend, &ctx, &x, m, k, ds.h, ds.w, ds.c);
        // empty pool and an under-covering pool both stand down to the
        // full screen + refine — byte-identical to the predictor path
        for pool in [Vec::new(), vec![3u32, 9]] {
            let (rows, reused) = corrector_golden_rows_batch(
                &backend,
                &[&ctx],
                &[x.as_slice()],
                &pool,
                m,
                k,
                ds.h,
                ds.w,
                ds.c,
            );
            assert!(!reused, "pool of {} cannot cover k_precise", pool.len());
            assert_eq!(rows[0], cold);
        }
        // an approximate backend stands down even with a covering pool: a
        // pool-restricted refine over it would change results
        let approx = crate::index::backend::ClusterPruned::build_with_threads(&ds, 12, 2, 3, 1);
        assert!(!approx.is_exact());
        let full: Vec<u32> = (0..ds.n as u32).collect();
        let (rows, reused) = corrector_golden_rows_batch(
            &approx,
            &[&ctx],
            &[x.as_slice()],
            &full,
            m,
            k,
            ds.h,
            ds.w,
            ds.c,
        );
        assert!(!reused);
        assert_eq!(
            rows[0],
            blended_golden_rows(&approx, &ctx, &x, m, k, ds.h, ds.w, ds.c)
        );
    }

    #[test]
    fn corrector_pool_respects_class_restrictions() {
        let (ds, sched) = setup();
        let backend = BatchedScan::new(1);
        let class = (0..ds.classes)
            .max_by_key(|&c| ds.class_rows[c].len())
            .unwrap() as u32;
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 9,
            class: Some(class),
        };
        let x = vec![0.05f32; ds.d];
        let (m, k) = (8usize, 4usize);
        // a mixed-class pool must be filtered to the query's class
        let pool: Vec<u32> = (0..ds.n as u32).collect();
        let (rows, reused) = corrector_golden_rows_batch(
            &backend,
            &[&ctx],
            &[x.as_slice()],
            &pool,
            m,
            k,
            ds.h,
            ds.w,
            ds.c,
        );
        assert!(reused, "the class slice of a full pool covers k_precise");
        assert!(rows[0].iter().all(|&r| ds.labels[r as usize] == class));
        // a pool with no rows of the class falls back — and the fallback
        // screen is itself class-restricted
        let other: Vec<u32> = (0..ds.n as u32)
            .filter(|&r| ds.labels[r as usize] != class)
            .collect();
        let (rows, reused) = corrector_golden_rows_batch(
            &backend,
            &[&ctx],
            &[x.as_slice()],
            &other,
            m,
            k,
            ds.h,
            ds.w,
            ds.c,
        );
        assert!(!reused);
        assert!(rows[0].iter().all(|&r| ds.labels[r as usize] == class));
    }

    #[test]
    fn golddiff_corrector_reuses_then_consumes_the_predictor_pool() {
        let (ds, sched) = setup();
        let mut gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden);
        let mut rng = crate::util::rng::Pcg64::new(31);
        let x: Vec<f32> = (0..ds.d).map(|_| rng.normal()).collect();
        let ctx_from = StepContext {
            ds: &ds,
            sched: &sched,
            step: 6,
            class: None,
        };
        let ctx_to = StepContext {
            ds: &ds,
            sched: &sched,
            step: 7,
            class: None,
        };
        let out = gd.denoise(&x, &ctx_from);
        assert!(out.support > 0);
        // the corrector reuses the predictor's pool (k shrinks along the
        // schedule, so the pool always covers the next step's k_precise)
        let corr = gd.corrector_denoise(&x, &ctx_to);
        assert!(corr.support > 0);
        assert!(corr.f_hat.iter().all(|v| v.is_finite()));
        assert_eq!(gd.corrector_refines, 1);
        assert_eq!(gd.screens_reused, 1);
        // the pool is consumed: a second corrector with no predictor in
        // between must fall back to a full screen…
        let corr2 = gd.corrector_denoise(&x, &ctx_to);
        assert_eq!(gd.corrector_refines, 2);
        assert_eq!(gd.screens_reused, 1);
        // …which makes it byte-identical to a plain denoise there
        let fresh = gd.denoise(&x, &ctx_to);
        assert_eq!(corr2.f_hat, fresh.f_hat);
        assert_eq!(corr2.support, fresh.support);
    }

    #[test]
    fn heun_sampling_pays_no_extra_screens_through_golddiff() {
        // the tentpole's CPU contract: a heun trajectory runs a corrector
        // at every non-terminal step yet pays exactly the ddim run's
        // coarse screens — every corrector rides the predictor's pool
        let (ds, sched) = setup();
        let run = |solver: crate::sampler::Solver| -> (u64, u64, u64) {
            let backend = Arc::new(BatchedScan::new(1));
            let mut gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden)
                .with_backend(backend.clone());
            let opts = crate::sampler::SamplerOpts {
                solver,
                ..Default::default()
            };
            let t = crate::sampler::sample(&mut gd, &ds, &sched, 9, opts);
            assert_eq!(t.fs.len(), sched.steps);
            (
                backend.stats().proxy_passes,
                gd.corrector_refines,
                gd.screens_reused,
            )
        };
        let (passes_ddim, corr_ddim, reused_ddim) = run(crate::sampler::Solver::Ddim);
        let (passes_heun, corr_heun, reused_heun) = run(crate::sampler::Solver::Heun);
        assert_eq!((corr_ddim, reused_ddim), (0, 0), "ddim runs no corrector");
        assert_eq!(
            corr_heun,
            (sched.steps - 1) as u64,
            "every non-terminal heun step runs a corrector"
        );
        assert!(reused_heun > 0, "low-noise correctors must reuse the pool");
        assert_eq!(
            passes_heun, passes_ddim,
            "corrector evals must not pay coarse screens"
        );
    }

    #[test]
    fn gauss_prefix_serves_closed_form_and_leaves_retrieval_untouched() {
        // the tentpole's CPU contract: ticks below the switch are the
        // moment-tier closed form (zero support, counted), and every
        // retrieval tick at/after the switch is byte-identical to gauss=off
        let (ds, sched) = setup();
        let gm = ds.gauss_moments().expect("resident corpora build lazily");
        let switch = 3usize;
        let mut off = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden);
        let mut on =
            GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden).with_gauss(switch);
        let mut rng = crate::util::rng::Pcg64::new(41);
        let x: Vec<f32> = (0..ds.d).map(|_| rng.normal()).collect();
        for step in 0..sched.steps {
            let ctx = StepContext {
                ds: &ds,
                sched: &sched,
                step,
                class: None,
            };
            let got = on.denoise(&x, &ctx);
            if step < switch {
                assert_eq!(got.support, 0, "gaussian ticks aggregate no rows");
                let want =
                    super::super::gaussian::gauss_result(gm, &x, ctx.alpha_bar(), None);
                assert_eq!(got.f_hat, want.f_hat, "step {step}");
            } else {
                let want = off.denoise(&x, &ctx);
                assert!(got.support > 0);
                assert_eq!(
                    got.f_hat, want.f_hat,
                    "retrieval segment must be byte-identical at step {step}"
                );
                assert_eq!(
                    on.golden_subset(&x, &ctx),
                    off.golden_subset(&x, &ctx),
                    "step {step}"
                );
            }
        }
        assert_eq!(on.gauss_ticks, switch as u64);
        assert_eq!(off.gauss_ticks, 0);
        // conditional gaussian ticks shrink toward the class moments
        let ctx0 = StepContext {
            ds: &ds,
            sched: &sched,
            step: 0,
            class: Some(2),
        };
        let cond = on.denoise(&x, &ctx0);
        let want = super::super::gaussian::gauss_result(gm, &x, ctx0.alpha_bar(), Some(2));
        assert_eq!(cond.f_hat, want.f_hat);
    }

    #[test]
    fn working_set_much_smaller_than_corpus() {
        let (ds, sched) = setup();
        let gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden);
        assert!(gd.working_set_bytes(&ds) < ds.bytes());
    }

    #[test]
    fn streamed_corpus_produces_byte_identical_subsets_and_outputs() {
        // Satellite: a data-free GoldDiff trajectory — subsets AND posterior
        // means — equals the resident one bit-for-bit, across every base
        // weighting, with a budget tight enough to force LRU cycling
        let (ds, sched) = setup();
        let dir = std::env::temp_dir().join("golddiff_denoiser_stream_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = crate::data::store::store_path(&dir, "cifar-sim");
        crate::data::store::save_sharded(&ds, &path, 4).unwrap();
        let st = crate::data::store::open_streaming(&path, 4, 1).unwrap();
        assert!(!st.is_resident());
        let x: Vec<f32> = {
            let mut rng = crate::util::rng::Pcg64::new(77);
            (0..ds.d).map(|_| rng.normal()).collect()
        };
        for base in [
            BaseWeighting::Golden,
            BaseWeighting::PcaSubspace { unbiased: true },
            BaseWeighting::PcaSubspace { unbiased: false },
            BaseWeighting::Kamb,
        ] {
            let mut a = GoldDiff::paper_defaults(&ds, &sched, base);
            let mut b = GoldDiff::paper_defaults(&st, &sched, base);
            for step in [0usize, 5, 9] {
                let ctx_r = StepContext {
                    ds: &ds,
                    sched: &sched,
                    step,
                    class: None,
                };
                let ctx_s = StepContext {
                    ds: &st,
                    sched: &sched,
                    step,
                    class: None,
                };
                let sa = a.golden_subset(&x, &ctx_r);
                let sb = b.golden_subset(&x, &ctx_s);
                assert_eq!(sa, sb, "{base:?} step {step}: subsets diverged");
                let fa = a.denoise(&x, &ctx_r).f_hat;
                let fb = b.denoise(&x, &ctx_s).f_hat;
                assert_eq!(fa, fb, "{base:?} step {step}: outputs diverged");
            }
        }
        assert!(st.source_stats().unwrap().rows_streamed > 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
