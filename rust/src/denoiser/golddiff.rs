//! GoldDiff — Dynamic Time-Aware Golden Subset Diffusion (the paper's
//! contribution, Sec. 3.4), as a plug-and-play wrapper over any base
//! weighting:
//!
//! 1. **Adaptive Coarse Screening** (Eq. 4): top-m_t rows by the s=1/4
//!    downsampled-ℓ2 proxy distance through a pluggable
//!    [`RetrievalBackend`] (flat / batched / cluster-pruned — see
//!    `index::backend`), with m_t *growing* as noise decreases.
//! 2. **Precision Golden Set Selection** (Eq. 5): exact full-resolution
//!    top-k_t inside the candidate pool, with k_t *shrinking* as noise
//!    decreases (Eq. 6).
//! 3. **Unbiased aggregation** (Sec. 3.2): a plain streaming softmax over
//!    the purified support — no weight-flattening tricks needed.
//!
//! `BaseWeighting` selects what Eq. 3's local operator is: plain pixel-space
//! logits (GoldDiff-on-Optimal), the PCA subspace (the paper's primary
//! configuration; `unbiased=false` gives the Tab. 6 WSS ablation arm), or
//! the Kamb patch weighting (Tab. 5). The base denoisers are built once and
//! cached in the `GoldDiff` struct — the seed rebuilt them every step.

use std::collections::HashSet;
use std::sync::Arc;

use super::kamb::KambDenoiser;
use super::pca::PcaDenoiser;
use super::softmax::{ss_aggregate, PosteriorStats};
use super::{descale, sqdist, DenoiseResult, Denoiser, StepContext};
use crate::data::dataset::Dataset;
use crate::data::synthetic::proxy_embed;
use crate::index::backend::{FlatScan, ProxyQuery, RetrievalBackend};
use crate::schedule::budget::BudgetSchedule;
use crate::schedule::noise::NoiseSchedule;

/// The shared GoldDiff retrieval used by both the CPU reference path and
/// the XLA engine (`coordinator::xla_denoiser`).
///
/// Two regimes, per the paper's Integration→Selection analysis (Sec. 3.3):
///
/// * the **precision fraction** (1−g) of the budget comes from the
///   coarse→fine pipeline — proxy top-m_t then exact top-k (Eqs. 4–5);
/// * the **breadth fraction** g comes from a *stratified* sample of the
///   support (every ⌈n/k⌉-th row with a step-dependent offset; rows are in
///   iid order so this is an unbiased random subset). At high noise the
///   estimator is a Monte-Carlo integrator — "robust to retrieval
///   imprecision but sensitive to sample sparsity" — so nearest-only
///   selection would bias the global mean; the breadth rows restore it.
///
/// As g → 0 this degenerates to pure precision retrieval; as g → 1 to a
/// broad Monte-Carlo subset. Duplicates are skipped, and the fill is
/// guaranteed to return exactly `min(k, support)` distinct rows.
pub fn blended_golden_rows(
    backend: &dyn RetrievalBackend,
    ctx: &StepContext,
    x_t: &[f32],
    m: usize,
    k: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Vec<u32> {
    blended_golden_rows_batch(backend, &[ctx], &[x_t], m, k, h, w, c)
        .pop()
        .unwrap_or_default()
}

/// Batched variant of [`blended_golden_rows`]: one coarse retrieval for the
/// whole group (the engine batches sequences that share a sampling point,
/// so every query shares (m, k, g)), then one batched exact refine over the
/// union of the group's candidate pools, then per-query breadth fill. With
/// the `BatchedScan` backend the group pays a *single* tiled pass over the
/// proxy table and a *single* union scan of the refine candidates.
///
/// All contexts must be at the same sampling point; classes may differ.
pub fn blended_golden_rows_batch(
    backend: &dyn RetrievalBackend,
    ctxs: &[&StepContext],
    xs: &[&[f32]],
    m: usize,
    k: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Vec<Vec<u32>> {
    assert_eq!(ctxs.len(), xs.len());
    if ctxs.is_empty() {
        return Vec::new();
    }
    debug_assert!(
        ctxs.iter().all(|ctx| ctx.step == ctxs[0].step),
        "a batch group must share one sampling point"
    );
    let ds = ctxs[0].ds;
    let g = ctxs[0].sched.g(ctxs[0].step) as f64;
    let k_breadth = ((k as f64) * g) as usize;
    let k_precise = k - k_breadth;

    let qs: Vec<Vec<f32>> = xs
        .iter()
        .zip(ctxs)
        .map(|(x, ctx)| descale(x, ctx.alpha_bar()))
        .collect();

    let mut per_query: Vec<Vec<u32>> = if k_precise > 0 {
        let proxies: Vec<Vec<f32>> = qs.iter().map(|q| proxy_embed(q, h, w, c)).collect();
        let queries: Vec<ProxyQuery> = proxies
            .iter()
            .zip(ctxs)
            .map(|(p, ctx)| ProxyQuery {
                proxy: p,
                class: ctx.class,
            })
            .collect();
        let cands = backend.top_m_batch(ds, &queries, m);
        // the batched refine ladder: one scan of the group's candidate-pool
        // union per tick, each full-resolution row loaded once and scored
        // against every query whose pool holds it, one bounded heap per
        // query (the trait default degrades to per-query refines)
        let qrefs: Vec<&[f32]> = qs.iter().map(|q| q.as_slice()).collect();
        let pools: Vec<&[u32]> = cands.iter().map(|p| p.as_slice()).collect();
        backend.refine_top_k_batch(ds, &qrefs, &pools, k_precise)
    } else {
        vec![Vec::new(); xs.len()]
    };

    for (rows, ctx) in per_query.iter_mut().zip(ctxs) {
        breadth_fill(ctx, rows, k, k_breadth);
    }
    per_query
}

/// Stratified breadth fill over the (class-restricted) support.
///
/// Invariant: on return `rows` holds exactly `min(k, support_size)`
/// distinct rows (the precise picks are always support members, so the
/// target clamps to what is achievable — strides colliding near `n` fall
/// through to the sequential top-up, which covers the whole support).
fn breadth_fill(ctx: &StepContext, rows: &mut Vec<u32>, k: usize, k_breadth: usize) {
    if k_breadth == 0 {
        return;
    }
    let support: &[u32] = match ctx.class {
        Some(y) => &ctx.ds.class_rows[y as usize],
        None => &[],
    };
    let n = if ctx.class.is_some() {
        support.len()
    } else {
        ctx.ds.n
    };
    let target = k.min(n);
    let row_at = |idx: usize| -> u32 {
        if ctx.class.is_some() {
            support[idx]
        } else {
            idx as u32
        }
    };
    let mut seen: HashSet<u32> = rows.iter().copied().collect();
    let stride = (n as f64 / k_breadth.max(1) as f64).max(1.0);
    let offset = (ctx.step as f64 * 0.618_033_99).fract() * stride;
    let mut pos = offset;
    while rows.len() < target && (pos as usize) < n {
        let gid = row_at(pos as usize);
        if seen.insert(gid) {
            rows.push(gid);
        }
        pos += stride;
    }
    // top up sequentially if strides collided with precise picks or with
    // each other near n
    let mut idx = 0usize;
    while rows.len() < target && idx < n {
        let gid = row_at(idx);
        if seen.insert(gid) {
            rows.push(gid);
        }
        idx += 1;
    }
    debug_assert_eq!(rows.len(), target, "breadth fill must reach its target");
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaseWeighting {
    /// pixel-space Gaussian-kernel logits + unbiased SS
    Golden,
    /// PCA-subspace logits; `unbiased=false` = biased WSS (ablation)
    PcaSubspace { unbiased: bool },
    /// Kamb patch-based weighting restricted to the golden subset
    Kamb,
}

pub struct GoldDiff {
    pub base: BaseWeighting,
    pub budget: BudgetSchedule,
    /// pluggable coarse-retrieval backend (shared with the engine)
    pub backend: Arc<dyn RetrievalBackend>,
    h: usize,
    w: usize,
    c: usize,
    /// cached base denoisers — built once per GoldDiff, not per step
    pca: Option<PcaDenoiser>,
    kamb: Option<KambDenoiser>,
    /// last step's budgets (telemetry)
    pub last_m: usize,
    pub last_k: usize,
}

impl GoldDiff {
    /// Paper defaults: m_min = k_max = N/10, m_max = N/4, k_min = N/20
    /// (Sec. 4.1), with the bucket ladder left un-padded on this CPU path
    /// (the XLA engine buckets via the manifest).
    pub fn paper_defaults(ds: &Dataset, _sched: &NoiseSchedule, base: BaseWeighting) -> GoldDiff {
        let buckets: Vec<usize> = (5..=17).map(|p| 1usize << p).collect();
        GoldDiff::new(ds, BudgetSchedule::paper_defaults(ds.n, &buckets), base)
    }

    pub fn new(ds: &Dataset, budget: BudgetSchedule, base: BaseWeighting) -> GoldDiff {
        let pca = match base {
            BaseWeighting::PcaSubspace { unbiased } => Some(PcaDenoiser::new(ds, unbiased)),
            _ => None,
        };
        let kamb = match base {
            BaseWeighting::Kamb => Some(KambDenoiser::new(ds)),
            _ => None,
        };
        GoldDiff {
            base,
            budget,
            backend: Arc::new(FlatScan::new(crate::util::threadpool::default_threads())),
            h: ds.h,
            w: ds.w,
            c: ds.c,
            pca,
            kamb,
            last_m: 0,
            last_k: 0,
        }
    }

    /// Swap the coarse-retrieval backend (the engine shares one per dataset).
    pub fn with_backend(mut self, backend: Arc<dyn RetrievalBackend>) -> Self {
        self.backend = backend;
        self
    }

    /// The coarse→fine retrieval: returns the golden subset S_t (row ids,
    /// nearest-first) for a query at sampling point `step`.
    pub fn golden_subset(&mut self, x_t: &[f32], ctx: &StepContext) -> Vec<u32> {
        self.golden_subsets(&[x_t], &[ctx]).pop().unwrap_or_default()
    }

    /// Batched retrieval for a group of sequences sharing one sampling
    /// point: one coarse pass over the proxy table (with the batched
    /// backend) instead of one per sequence.
    pub fn golden_subsets(&mut self, xs: &[&[f32]], ctxs: &[&StepContext]) -> Vec<Vec<u32>> {
        if ctxs.is_empty() {
            return Vec::new();
        }
        let b = self.budget.at(ctxs[0].sched, ctxs[0].step);
        self.last_m = b.m;
        self.last_k = b.k;
        blended_golden_rows_batch(
            self.backend.as_ref(),
            ctxs,
            xs,
            b.m,
            b.k,
            self.h,
            self.w,
            self.c,
        )
    }
}

impl Denoiser for GoldDiff {
    fn name(&self) -> String {
        match self.base {
            BaseWeighting::Golden => "golddiff".into(),
            BaseWeighting::PcaSubspace { unbiased: true } => "golddiff-pca".into(),
            BaseWeighting::PcaSubspace { unbiased: false } => "golddiff-wss".into(),
            BaseWeighting::Kamb => "golddiff-kamb".into(),
        }
    }

    fn denoise(&mut self, x_t: &[f32], ctx: &StepContext) -> DenoiseResult {
        let golden = self.golden_subset(x_t, ctx);
        let support = golden.len();
        let ds = ctx.ds;
        match self.base {
            BaseWeighting::Golden => {
                let q = descale(x_t, ctx.alpha_bar());
                let scale = ctx.logit_scale();
                let (f_hat, stats): (Vec<f32>, PosteriorStats) = ss_aggregate(
                    ds.d,
                    golden.iter().map(|&gid| {
                        let row = ds.row(gid as usize);
                        (-sqdist(&q, row) * scale, row)
                    }),
                );
                DenoiseResult {
                    f_hat,
                    stats,
                    support,
                }
            }
            BaseWeighting::PcaSubspace { .. } => {
                let base = self.pca.as_mut().expect("pca base cached at construction");
                base.subset = Some(golden);
                let mut out = base.denoise(x_t, ctx);
                out.support = support;
                out
            }
            BaseWeighting::Kamb => {
                let base = self
                    .kamb
                    .as_mut()
                    .expect("kamb base cached at construction");
                base.subset = Some(golden);
                let mut out = base.denoise(x_t, ctx);
                out.support = support;
                out
            }
        }
    }

    fn working_set_bytes(&self, ds: &Dataset) -> u64 {
        // proxy table + gathered golden subset + scratch — NOT the corpus
        // resident per-query working set (the corpus itself is shared,
        // dominant term is the m_max gather)
        (ds.n * ds.proxy_d + self.budget.m_max * ds.d + 4 * ds.d) as u64 * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;
    use crate::index::backend::BatchedScan;
    use crate::schedule::noise::ScheduleKind;

    fn setup() -> (Dataset, NoiseSchedule) {
        let mut spec = preset("cifar-sim").unwrap().clone();
        spec.n = 500;
        (
            Dataset::synthesize(&spec, 6),
            NoiseSchedule::new(ScheduleKind::DdpmLinear, 10),
        )
    }

    #[test]
    fn golden_subset_sizes_follow_schedule() {
        let (ds, sched) = setup();
        let mut gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden);
        let x = vec![0.1f32; ds.d];
        let ctx0 = StepContext {
            ds: &ds,
            sched: &sched,
            step: 0,
            class: None,
        };
        let s0 = gd.golden_subset(&x, &ctx0);
        let (m0, k0) = (gd.last_m, gd.last_k);
        let ctx9 = StepContext {
            ds: &ds,
            sched: &sched,
            step: 9,
            class: None,
        };
        let s9 = gd.golden_subset(&x, &ctx9);
        let (m9, k9) = (gd.last_m, gd.last_k);
        assert_eq!(s0.len(), k0);
        assert_eq!(s9.len(), k9);
        assert!(m9 > m0, "retrieval scope must grow: {m0} -> {m9}");
        assert!(k9 < k0, "aggregation budget must shrink: {k0} -> {k9}");
    }

    #[test]
    fn low_noise_golden_subset_contains_true_neighbour() {
        let (ds, sched) = setup();
        let mut gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden);
        let step = 9;
        let a = sched.alpha_bar(step);
        let x_t: Vec<f32> = ds.row(42).iter().map(|&v| v * a.sqrt()).collect();
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step,
            class: None,
        };
        let s = gd.golden_subset(&x_t, &ctx);
        assert_eq!(s[0], 42, "exact refine must put the true neighbour first");
    }

    #[test]
    fn golddiff_tracks_optimal_at_low_noise() {
        // Theorem 1 consequence: at low noise, truncation error is
        // negligible, so GoldDiff ≈ Optimal full scan.
        let (ds, sched) = setup();
        let mut gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden);
        let mut opt = super::super::optimal::OptimalDenoiser::new();
        let step = 9;
        let a = sched.alpha_bar(step);
        let x_t: Vec<f32> = ds.row(3).iter().map(|&v| v * a.sqrt()).collect();
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step,
            class: None,
        };
        let f_gd = gd.denoise(&x_t, &ctx).f_hat;
        let f_opt = opt.denoise(&x_t, &ctx).f_hat;
        let err: f32 = f_gd
            .iter()
            .zip(&f_opt)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 1e-3, "max deviation from optimal {err}");
    }

    #[test]
    fn conditional_subset_stays_in_class() {
        let (ds, sched) = setup();
        let mut gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden);
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 5,
            class: Some(3),
        };
        let s = gd.golden_subset(&vec![0.0; ds.d], &ctx);
        assert!(!s.is_empty());
        assert!(s.iter().all(|&i| ds.labels[i as usize] == 3));
    }

    #[test]
    fn all_base_weightings_produce_finite_output() {
        let (ds, sched) = setup();
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 5,
            class: None,
        };
        for base in [
            BaseWeighting::Golden,
            BaseWeighting::PcaSubspace { unbiased: true },
            BaseWeighting::PcaSubspace { unbiased: false },
            BaseWeighting::Kamb,
        ] {
            let mut gd = GoldDiff::paper_defaults(&ds, &sched, base);
            let out = gd.denoise(&vec![0.2; ds.d], &ctx);
            assert!(out.f_hat.iter().all(|v| v.is_finite()), "{base:?}");
            assert!(out.support > 0);
        }
    }

    #[test]
    fn cached_base_denoiser_is_reused_across_steps() {
        // the seed rebuilt PcaDenoiser/KambDenoiser on every denoise call;
        // the cached instances must keep producing identical output
        let (ds, sched) = setup();
        let mut gd = GoldDiff::paper_defaults(
            &ds,
            &sched,
            BaseWeighting::PcaSubspace { unbiased: true },
        );
        let x = vec![0.15f32; ds.d];
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 4,
            class: None,
        };
        let a = gd.denoise(&x, &ctx).f_hat;
        let b = gd.denoise(&x, &ctx).f_hat;
        assert_eq!(a, b, "cached base must be deterministic across calls");
        assert!(gd.pca.is_some() && gd.kamb.is_none());
    }

    #[test]
    fn breadth_fill_returns_exactly_k_distinct_rows_at_tiny_n() {
        // regression (satellite): strides colliding near n must fall back
        // to the sequential top-up so exactly min(k, n) rows return
        let mut spec = preset("cifar-sim").unwrap().clone();
        spec.n = 24;
        let ds = Dataset::synthesize(&spec, 17);
        let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
        let backend = FlatScan::new(1);
        let x = vec![0.2f32; ds.d];
        // step 0 = deepest noise: g ≈ 1, the fill is breadth-dominated
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 0,
            class: None,
        };
        for k in [1usize, 7, 23, 24, 40] {
            let rows = blended_golden_rows(&backend, &ctx, &x, 6, k, ds.h, ds.w, ds.c);
            let want = k.min(ds.n);
            assert_eq!(rows.len(), want, "k={k}");
            let distinct: HashSet<u32> = rows.iter().copied().collect();
            assert_eq!(distinct.len(), want, "k={k} duplicates");
            assert!(rows.iter().all(|&r| (r as usize) < ds.n));
        }
    }

    #[test]
    fn breadth_fill_conditional_clamps_to_class_support() {
        let mut spec = preset("cifar-sim").unwrap().clone();
        spec.n = 40;
        let ds = Dataset::synthesize(&spec, 19);
        let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
        let backend = FlatScan::new(1);
        let x = vec![0.1f32; ds.d];
        // pick the best-populated class (tiny n can leave classes empty)
        let class = (0..ds.classes)
            .max_by_key(|&c| ds.class_rows[c].len())
            .unwrap() as u32;
        let support = ds.class_rows[class as usize].len();
        assert!(support > 0);
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 0,
            class: Some(class),
        };
        let rows = blended_golden_rows(&backend, &ctx, &x, 8, support + 10, ds.h, ds.w, ds.c);
        assert_eq!(rows.len(), support, "cannot exceed the class support");
        assert!(rows.iter().all(|&r| ds.labels[r as usize] == class));
    }

    #[test]
    fn batched_subsets_match_single_query_subsets() {
        let (ds, sched) = setup();
        let mut gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden)
            .with_backend(Arc::new(BatchedScan::new(2)));
        let xs_data: Vec<Vec<f32>> = (0..6)
            .map(|i| {
                let mut rng = crate::util::rng::Pcg64::new(100 + i);
                (0..ds.d).map(|_| rng.normal()).collect()
            })
            .collect();
        for step in [0usize, 5, 9] {
            let ctx = StepContext {
                ds: &ds,
                sched: &sched,
                step,
                class: None,
            };
            let xs: Vec<&[f32]> = xs_data.iter().map(|x| x.as_slice()).collect();
            let ctxs: Vec<&StepContext> = xs.iter().map(|_| &ctx).collect();
            let batch = gd.golden_subsets(&xs, &ctxs);
            for (i, x) in xs.iter().enumerate() {
                let solo = gd.golden_subset(x, &ctx);
                assert_eq!(batch[i], solo, "step {step} seq {i}");
            }
        }
    }

    #[test]
    fn working_set_much_smaller_than_corpus() {
        let (ds, sched) = setup();
        let gd = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden);
        assert!(gd.working_set_bytes(&ds) < ds.bytes());
    }
}
