//! Wiener filter (Wiener 1949): fit a single Gaussian N(mean, diag(var)) to
//! the corpus at build time and denoise by per-dimension shrinkage. The
//! only baseline whose per-step cost is independent of N (Tab. 1) — fast
//! but markedly less accurate on multimodal data.

use super::softmax::PosteriorStats;
use super::{descale, DenoiseResult, Denoiser, StepContext};
use crate::data::dataset::Dataset;

#[derive(Debug, Clone)]
pub struct WienerDenoiser {
    mean: Vec<f32>,
    var: Vec<f32>,
}

impl WienerDenoiser {
    pub fn new(ds: &Dataset) -> Self {
        WienerDenoiser {
            mean: ds.mean.clone(),
            var: ds.var.clone(),
        }
    }
}

impl Denoiser for WienerDenoiser {
    fn name(&self) -> String {
        "wiener".into()
    }

    fn denoise(&mut self, x_t: &[f32], ctx: &StepContext) -> DenoiseResult {
        let a = ctx.alpha_bar();
        let sigma2 = (1.0 - a) / a.max(1e-12);
        let q = descale(x_t, a);
        let f_hat: Vec<f32> = (0..q.len())
            .map(|j| {
                let g = self.var[j] / (self.var[j] + sigma2);
                self.mean[j] + g * (q[j] - self.mean[j])
            })
            .collect();
        DenoiseResult {
            f_hat,
            stats: PosteriorStats::zero(),
            support: 0,
        }
    }

    fn working_set_bytes(&self, _ds: &Dataset) -> u64 {
        (self.mean.len() + self.var.len()) as u64 * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;
    use crate::schedule::noise::{NoiseSchedule, ScheduleKind};

    #[test]
    fn shrinks_to_mean_at_high_noise_and_identity_at_low() {
        let mut spec = preset("mnist-sim").unwrap().clone();
        spec.n = 150;
        let ds = Dataset::synthesize(&spec, 1);
        let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
        let mut den = WienerDenoiser::new(&ds);

        // high noise (step 0): output ≈ corpus mean
        let ctx0 = StepContext {
            ds: &ds,
            sched: &sched,
            step: 0,
            class: None,
        };
        let out = den.denoise(&vec![0.05; ds.d], &ctx0);
        let dev: f32 = out
            .f_hat
            .iter()
            .zip(&ds.mean)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(dev < 0.05, "high-noise Wiener must shrink to mean: {dev}");

        // low noise (step 9): output ≈ descaled query
        let ctx9 = StepContext {
            ds: &ds,
            sched: &sched,
            step: 9,
            class: None,
        };
        let a = sched.alpha_bar(9);
        let x0 = ds.row(3).to_vec();
        let x_t: Vec<f32> = x0.iter().map(|&v| v * a.sqrt()).collect();
        let out = den.denoise(&x_t, &ctx9);
        let err: f32 = out
            .f_hat
            .iter()
            .zip(&x0)
            .map(|(p, q)| (p - q).abs())
            .fold(0.0, f32::max);
        assert!(err < 0.25, "low-noise Wiener should pass the query: {err}");
    }

    #[test]
    fn working_set_is_tiny() {
        let mut spec = preset("mnist-sim").unwrap().clone();
        spec.n = 150;
        let ds = Dataset::synthesize(&spec, 1);
        let den = WienerDenoiser::new(&ds);
        assert!(den.working_set_bytes(&ds) < ds.bytes() / 10);
    }
}
