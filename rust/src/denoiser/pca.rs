//! PCA denoiser (Lukoianov et al. 2025) — the paper's state-of-the-art
//! analytical baseline.
//!
//! Posterior weights are computed in a *local PCA subspace*: pick the
//! nearest k-means cluster to the query, project query and candidates onto
//! that cluster's rank-R orthonormal basis, and take Gaussian-kernel logits
//! on the projections (Eq. 3's P_i). Aggregation of the full-D candidates
//! then uses either:
//!
//! * the published **biased Weighted Streaming Softmax** (batch-averaged;
//!   `unbiased = false`) — reproducing the over-smoothing of Fig. 2, or
//! * the **unbiased streaming softmax** (`unbiased = true`) — the paper's
//!   "PCA (Unbiased)" arm, which instead exhibits the memorisation /
//!   patch-collage failure at scale (Sec. 4.2).
//!
//! Projections are recomputed per step (basis and centring depend on the
//! query), keeping the published O(N·R·D)-per-step cost shape of Tab. 1.

use super::softmax::{PosteriorStats, StreamingSoftmax, WssAccum};
use super::{descale, DenoiseResult, Denoiser, StepContext};
use crate::data::dataset::Dataset;

/// Number of WSS averaging batches (matches compile/presets.WSS_BLOCKS).
pub const WSS_BLOCKS: usize = 8;

#[derive(Debug)]
pub struct PcaDenoiser {
    rank: usize,
    pub unbiased: bool,
    /// optional support restriction (GoldDiff wrapper)
    pub subset: Option<Vec<u32>>,
}

impl PcaDenoiser {
    pub fn new(ds: &Dataset, unbiased: bool) -> Self {
        PcaDenoiser {
            rank: crate::data::dataset::PCA_RANK.min(ds.d),
            unbiased,
            subset: None,
        }
    }

    /// The query's subspace coordinates: z_q = B(q − μ).
    fn project_query(basis: &[f32], center: &[f32], q: &[f32], r: usize, d: usize) -> Vec<f32> {
        let mut zq = vec![0.0f32; r];
        for (rr, z) in zq.iter_mut().enumerate() {
            let b = &basis[rr * d..(rr + 1) * d];
            let mut acc = 0.0f32;
            for j in 0..d {
                acc += (q[j] - center[j]) * b[j];
            }
            *z = acc;
        }
        zq
    }

    /// One row's subspace logit: ℓ_i = -||z_q - B(x_i - μ)||² · scale.
    #[inline]
    fn row_logit(
        basis: &[f32],
        center: &[f32],
        zq: &[f32],
        row: &[f32],
        scale: f32,
        d: usize,
    ) -> f32 {
        let mut dist = 0.0f32;
        for (rr, &z) in zq.iter().enumerate() {
            let b = &basis[rr * d..(rr + 1) * d];
            let mut zc = 0.0f32;
            for j in 0..d {
                zc += (row[j] - center[j]) * b[j];
            }
            let dd = z - zc;
            dist += dd * dd;
        }
        -dist * scale
    }
}

impl Denoiser for PcaDenoiser {
    fn name(&self) -> String {
        if self.unbiased {
            "pca-unbiased".into()
        } else {
            "pca".into()
        }
    }

    fn denoise(&mut self, x_t: &[f32], ctx: &StepContext) -> DenoiseResult {
        let ds = ctx.ds;
        let q = descale(x_t, ctx.alpha_bar());
        let rows: Vec<u32> = match &self.subset {
            Some(s) => s.clone(),
            None => ctx.rows().collect(),
        };
        let scale = ctx.logit_scale();
        let cluster = ds.nearest_cluster(&q);
        let (basis, center) = ds.pca_basis(cluster);
        let (r, d) = (self.rank, ds.d);
        let zq = Self::project_query(basis, center, &q, r, d);

        // one fused pass over the support: project, logit, aggregate —
        // same per-row math and push order as the old logits-then-items
        // two-pass, so the output is bit-identical while the rows stream
        // through the source once (the streamed PCA fit never holds more
        // than the LRU budget resident)
        let (f_hat, stats): (Vec<f32>, PosteriorStats) = if self.unbiased {
            let mut acc = StreamingSoftmax::new(d);
            ds.visit_rows(rows.iter().copied(), |_, row| {
                acc.push(Self::row_logit(basis, center, &zq, row, scale, d), row);
            });
            acc.finish()
        } else {
            let mut acc = WssAccum::new(d, rows.len().max(1), WSS_BLOCKS);
            ds.visit_rows(rows.iter().copied(), |_, row| {
                acc.push(Self::row_logit(basis, center, &zq, row, scale, d), row);
            });
            acc.finish()
        };
        DenoiseResult {
            f_hat,
            stats,
            support: rows.len(),
        }
    }

    fn working_set_bytes(&self, ds: &Dataset) -> u64 {
        (ds.n * ds.d + self.rank * ds.d + ds.n + 4 * ds.d) as u64 * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;
    use crate::schedule::noise::{NoiseSchedule, ScheduleKind};

    fn setup() -> (Dataset, NoiseSchedule) {
        let mut spec = preset("cifar-sim").unwrap().clone();
        spec.n = 300;
        (
            Dataset::synthesize(&spec, 4),
            NoiseSchedule::new(ScheduleKind::DdpmLinear, 10),
        )
    }

    #[test]
    fn unbiased_low_noise_recovers_neighbour() {
        let (ds, sched) = setup();
        let mut den = PcaDenoiser::new(&ds, true);
        let step = 9;
        let a = sched.alpha_bar(step);
        let x0 = ds.row(31).to_vec();
        let x_t: Vec<f32> = x0.iter().map(|&v| v * a.sqrt()).collect();
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step,
            class: None,
        };
        let out = den.denoise(&x_t, &ctx);
        let mse: f32 = out
            .f_hat
            .iter()
            .zip(&x0)
            .map(|(p, t)| (p - t) * (p - t))
            .sum::<f32>()
            / ds.d as f32;
        assert!(mse < 0.1, "mse {mse}");
    }

    #[test]
    fn biased_wss_is_smoother_than_unbiased() {
        // the Fig. 2 effect: WSS output is closer to the corpus mean
        let (ds, sched) = setup();
        let step = 8;
        let a = sched.alpha_bar(step);
        let x0 = ds.row(11).to_vec();
        let x_t: Vec<f32> = x0.iter().map(|&v| v * a.sqrt()).collect();
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step,
            class: None,
        };
        let f_ss = PcaDenoiser::new(&ds, true).denoise(&x_t, &ctx).f_hat;
        let f_wss = PcaDenoiser::new(&ds, false).denoise(&x_t, &ctx).f_hat;
        let dist = |f: &[f32], g: &[f32]| -> f32 {
            f.iter().zip(g).map(|(a, b)| (a - b) * (a - b)).sum()
        };
        assert!(
            dist(&f_wss, &ds.mean) < dist(&f_ss, &ds.mean),
            "WSS should be pulled towards the mean"
        );
    }

    #[test]
    fn subset_restriction_shrinks_support() {
        let (ds, sched) = setup();
        let mut den = PcaDenoiser::new(&ds, true);
        den.subset = Some((0..32u32).collect());
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 5,
            class: None,
        };
        let out = den.denoise(&vec![0.1; ds.d], &ctx);
        assert_eq!(out.support, 32);
    }

    #[test]
    fn output_in_convex_hull_when_unbiased() {
        let (ds, sched) = setup();
        let mut den = PcaDenoiser::new(&ds, true);
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 4,
            class: None,
        };
        let out = den.denoise(&vec![0.3; ds.d], &ctx);
        // convex combination of rows ⇒ within global min/max per dim
        for j in (0..ds.d).step_by(97) {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for i in 0..ds.n {
                let v = ds.row(i)[j];
                lo = lo.min(v);
                hi = hi.max(v);
            }
            assert!(out.f_hat[j] >= lo - 1e-4 && out.f_hat[j] <= hi + 1e-4);
        }
    }
}
