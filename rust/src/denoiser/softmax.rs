//! Streaming softmax aggregation — the rust twins of the L1 Pallas kernels.
//!
//! * `ss_aggregate` — *unbiased* one-pass online softmax (Dao et al. 2022):
//!   running max / denominator / weighted accumulator; bit-for-bit the same
//!   recurrence as `kernels/golden_aggregate.py`.
//! * `wss_aggregate` — the *biased* Weighted Streaming Softmax of the PCA
//!   baseline (Sec. 3.2): candidates are processed in batches, each batch
//!   contributes its own softmax mean, batch means are averaged — the
//!   weight-flattening trick that causes the paper's over-smoothing.

/// Posterior telemetry shared by every denoiser.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PosteriorStats {
    pub max_logit: f32,
    pub logsumexp: f32,
    pub entropy: f32,
    pub top1_weight: f32,
}

impl PosteriorStats {
    pub fn zero() -> Self {
        PosteriorStats {
            max_logit: 0.0,
            logsumexp: 0.0,
            entropy: 0.0,
            top1_weight: 0.0,
        }
    }
}

/// Online-softmax accumulator over (logit, row) pairs.
pub struct StreamingSoftmax {
    d: usize,
    m: f32,
    l: f32,
    s: f32, // sum p * logit (for entropy)
    acc: Vec<f32>,
    count: usize,
}

impl StreamingSoftmax {
    pub fn new(d: usize) -> Self {
        StreamingSoftmax {
            d,
            m: f32::NEG_INFINITY,
            l: 0.0,
            s: 0.0,
            acc: vec![0.0; d],
            count: 0,
        }
    }

    #[inline]
    pub fn push(&mut self, logit: f32, row: &[f32]) {
        debug_assert_eq!(row.len(), self.d);
        if logit > self.m {
            let corr = if self.m.is_finite() {
                (self.m - logit).exp()
            } else {
                0.0
            };
            self.l *= corr;
            self.s *= corr;
            for v in self.acc.iter_mut() {
                *v *= corr;
            }
            self.m = logit;
        }
        let p = (logit - self.m).exp();
        self.l += p;
        self.s += p * logit;
        for (a, &x) in self.acc.iter_mut().zip(row) {
            *a += p * x;
        }
        self.count += 1;
    }

    /// Finalise into (posterior mean, stats).
    pub fn finish(self) -> (Vec<f32>, PosteriorStats) {
        assert!(self.count > 0, "no rows aggregated");
        let mut out = self.acc;
        let inv = 1.0 / self.l;
        for v in out.iter_mut() {
            *v *= inv;
        }
        let lse = self.m + self.l.ln();
        let mean_logit = self.s / self.l;
        (
            out,
            PosteriorStats {
                max_logit: self.m,
                logsumexp: lse,
                entropy: (lse - mean_logit).max(0.0),
                top1_weight: (self.m - lse).exp(),
            },
        )
    }
}

/// Unbiased streaming aggregation of `(logit_i, row_i)` over an iterator.
pub fn ss_aggregate<'a>(
    d: usize,
    items: impl IntoIterator<Item = (f32, &'a [f32])>,
) -> (Vec<f32>, PosteriorStats) {
    let mut acc = StreamingSoftmax::new(d);
    for (logit, row) in items {
        acc.push(logit, row);
    }
    acc.finish()
}

/// Streaming form of the biased Weighted Streaming Softmax: push
/// `(logit, row)` pairs **in order**; a block boundary lands every
/// `⌈n/blocks⌉` pushes — exactly where the sliced [`wss_aggregate`] cuts —
/// so the result is bit-identical while rows stream through one pass (no
/// resident item list; what the out-of-core PCA arm aggregates with).
pub struct WssAccum {
    d: usize,
    per: usize,
    in_block: usize,
    block: StreamingSoftmax,
    /// exact global stats for telemetry come from a parallel SS pass
    global: StreamingSoftmax,
    /// running sum of finished block means, accumulated in block order
    sum: Vec<f32>,
    blocks_done: usize,
}

impl WssAccum {
    /// `n` is the total number of pushes to come (the support size) —
    /// needed up front to place the block boundaries like the sliced form.
    pub fn new(d: usize, n: usize, blocks: usize) -> WssAccum {
        assert!(n > 0, "no rows to aggregate");
        let blocks = blocks.clamp(1, n);
        WssAccum {
            d,
            per: n.div_ceil(blocks),
            in_block: 0,
            block: StreamingSoftmax::new(d),
            global: StreamingSoftmax::new(d),
            sum: vec![0.0f32; d],
            blocks_done: 0,
        }
    }

    pub fn push(&mut self, logit: f32, row: &[f32]) {
        self.block.push(logit, row);
        self.global.push(logit, row);
        self.in_block += 1;
        if self.in_block == self.per {
            self.flush_block();
        }
    }

    fn flush_block(&mut self) {
        let block = std::mem::replace(&mut self.block, StreamingSoftmax::new(self.d));
        let (mean, _) = block.finish();
        for (o, &v) in self.sum.iter_mut().zip(&mean) {
            *o += v;
        }
        self.blocks_done += 1;
        self.in_block = 0;
    }

    pub fn finish(mut self) -> (Vec<f32>, PosteriorStats) {
        if self.in_block > 0 {
            self.flush_block();
        }
        let inv = 1.0 / self.blocks_done as f32;
        let mut out = self.sum;
        for v in out.iter_mut() {
            *v *= inv;
        }
        let (_, stats) = self.global.finish();
        (out, stats)
    }
}

/// Biased Weighted Streaming Softmax with batch-level averaging over
/// `blocks` equal batches (the PCA baseline's flattening heuristic).
/// Implemented on [`WssAccum`] so the sliced and streaming forms are one
/// code path.
pub fn wss_aggregate<'a>(
    d: usize,
    items: &[(f32, &'a [f32])],
    blocks: usize,
) -> (Vec<f32>, PosteriorStats) {
    assert!(!items.is_empty());
    let mut acc = WssAccum::new(d, items.len(), blocks);
    for &(logit, row) in items {
        acc.push(logit, row);
    }
    acc.finish()
}

/// Exact (two-pass) normalised weights of a logit slice — test oracle and
/// Fig. 1/3a telemetry.
pub fn exact_softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&l| (l - m).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    fn naive_agg(logits: &[f32], rows: &[Vec<f32>]) -> Vec<f32> {
        let w = exact_softmax(logits);
        let d = rows[0].len();
        let mut out = vec![0.0f32; d];
        for (wi, row) in w.iter().zip(rows) {
            for j in 0..d {
                out[j] += wi * row[j];
            }
        }
        out
    }

    #[test]
    fn ss_matches_naive_softmax() {
        forall(7, 100, |rng| {
            let k = gen::usize_in(rng, 1, 200);
            let d = gen::usize_in(rng, 1, 32);
            let logits: Vec<f32> = (0..k).map(|_| rng.normal() * 10.0).collect();
            let rows: Vec<Vec<f32>> = (0..k).map(|_| gen::vec_normal(rng, d, 2.0)).collect();
            let (got, stats) =
                ss_aggregate(d, logits.iter().copied().zip(rows.iter().map(|r| r.as_slice())));
            let want = naive_agg(&logits, &rows);
            for j in 0..d {
                crate::prop_assert!(
                    (got[j] - want[j]).abs() < 1e-3,
                    "dim {j}: {} vs {}",
                    got[j],
                    want[j]
                );
            }
            let w = exact_softmax(&logits);
            let top1 = w.iter().copied().fold(0.0f32, f32::max);
            crate::prop_assert!(
                (stats.top1_weight - top1).abs() < 1e-3,
                "top1 {} vs {}",
                stats.top1_weight,
                top1
            );
            Ok(())
        });
    }

    #[test]
    fn ss_is_shift_invariant() {
        let rows = vec![vec![1.0f32, 0.0], vec![0.0, 1.0]];
        let (a, _) = ss_aggregate(2, [(0.3f32, rows[0].as_slice()), (0.9, rows[1].as_slice())]);
        let (b, _) = ss_aggregate(
            2,
            [(100.3f32, rows[0].as_slice()), (100.9, rows[1].as_slice())],
        );
        for j in 0..2 {
            assert!((a[j] - b[j]).abs() < 1e-6);
        }
    }

    #[test]
    fn ss_survives_extreme_logits() {
        let rows = vec![vec![1.0f32], vec![2.0]];
        let (out, stats) =
            ss_aggregate(1, [(-3e4f32, rows[0].as_slice()), (3e4, rows[1].as_slice())]);
        assert!((out[0] - 2.0).abs() < 1e-6);
        assert!(stats.logsumexp.is_finite());
        assert!((stats.top1_weight - 1.0).abs() < 1e-6);
    }

    #[test]
    fn wss_flattens_towards_block_mean_average() {
        // One dominant logit; SS returns its row, WSS averages block means
        // so the dominated blocks still pull the answer away.
        let rows: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32]).collect();
        let mut items: Vec<(f32, &[f32])> =
            rows.iter().map(|r| (0.0f32, r.as_slice())).collect();
        items[0].0 = 50.0; // dominant
        let (ss, _) = ss_aggregate(1, items.iter().copied());
        let (wss, _) = wss_aggregate(1, &items, 4);
        assert!((ss[0] - 0.0).abs() < 1e-3, "SS must track the dominant row");
        assert!(wss[0] > 1.0, "WSS must be flattened: {}", wss[0]);
    }

    #[test]
    fn wss_single_block_equals_ss() {
        let rows: Vec<Vec<f32>> = (0..16).map(|i| vec![i as f32, -(i as f32)]).collect();
        let items: Vec<(f32, &[f32])> = rows
            .iter()
            .enumerate()
            .map(|(i, r)| ((i as f32) * 0.3, r.as_slice()))
            .collect();
        let (ss, _) = ss_aggregate(2, items.iter().copied());
        let (wss, _) = wss_aggregate(2, &items, 1);
        for j in 0..2 {
            assert!((ss[j] - wss[j]).abs() < 1e-4);
        }
    }

    #[test]
    fn entropy_limits() {
        let rows: Vec<Vec<f32>> = (0..64).map(|_| vec![0.0f32]).collect();
        let uniform: Vec<(f32, &[f32])> = rows.iter().map(|r| (1.0f32, r.as_slice())).collect();
        let (_, stats) = ss_aggregate(1, uniform.iter().copied());
        assert!((stats.entropy - (64.0f32).ln()).abs() < 1e-3);
        let mut peaked = uniform.clone();
        peaked[5].0 = 1e4;
        let (_, stats) = ss_aggregate(1, peaked.iter().copied());
        assert!(stats.entropy < 1e-3);
    }
}
