//! Analytical denoisers: the paper's baselines (Optimal, Wiener, Kamb, PCA)
//! and the GoldDiff coarse→fine wrapper (Sec. 3.4), as pure-rust reference
//! implementations.
//!
//! These CPU paths are the *semantic specification*: the XLA-artifact-backed
//! engine (`coordinator`) must agree with them numerically (integration
//! tests), and the bench harnesses use whichever path an experiment calls
//! for. All share the empirical-Bayes convention of Sec. 3.1:
//!
//!   q = x_t/√ᾱ_t ,  ℓ_i = -||q - x_i||² / (2σ_t²) ,  σ_t² = (1-ᾱ_t)/ᾱ_t

pub mod gaussian;
pub mod golddiff;
pub mod kamb;
pub mod optimal;
pub mod pca;
pub mod softmax;
pub mod wiener;

use crate::data::dataset::Dataset;
use crate::schedule::noise::NoiseSchedule;
pub use softmax::PosteriorStats;

/// Per-step context handed to a denoiser.
pub struct StepContext<'a> {
    pub ds: &'a Dataset,
    pub sched: &'a NoiseSchedule,
    /// sampling point index (0 = deepest noise)
    pub step: usize,
    /// conditional class (ImageNet-sim)
    pub class: Option<u32>,
}

impl StepContext<'_> {
    pub fn alpha_bar(&self) -> f32 {
        self.sched.alpha_bar(self.step)
    }

    pub fn logit_scale(&self) -> f32 {
        self.sched.logit_scale(self.step)
    }

    /// Row ids the posterior may range over (class shard when conditional).
    pub fn rows(&self) -> RowIter<'_> {
        match self.class {
            Some(y) => RowIter::Class(self.ds.class_rows[y as usize].iter()),
            None => RowIter::All(0..self.ds.n as u32),
        }
    }
}

pub enum RowIter<'a> {
    All(std::ops::Range<u32>),
    Class(std::slice::Iter<'a, u32>),
}

impl Iterator for RowIter<'_> {
    type Item = u32;
    fn next(&mut self) -> Option<u32> {
        match self {
            RowIter::All(r) => r.next(),
            RowIter::Class(it) => it.next().copied(),
        }
    }
}

/// One denoising evaluation: the posterior mean plus telemetry.
#[derive(Debug, Clone)]
pub struct DenoiseResult {
    pub f_hat: Vec<f32>,
    pub stats: PosteriorStats,
    /// number of candidates actually aggregated (golden-subset size)
    pub support: usize,
}

/// The analytical-denoiser interface all methods implement.
///
/// Deliberately *not* `Send`: the XLA-backed implementation holds PJRT
/// handles that live on the engine's executor thread. CPU implementations
/// are all `Send` structs and can be moved across threads directly.
pub trait Denoiser {
    fn name(&self) -> String;

    /// Posterior-mean estimate f̂(x_t, t).
    fn denoise(&mut self, x_t: &[f32], ctx: &StepContext) -> DenoiseResult;

    /// The second score evaluation of a higher-order solver step
    /// (`sampler::Solver::{Heun, Dpm2}`). The provisional state `x_t` sits
    /// a fraction of a step ahead of the predictor's tick, so its golden
    /// subset barely moves — retrieval-backed implementations may reuse the
    /// predictor tick's candidate pool instead of paying a second coarse
    /// screen, as long as the aggregation stays exact over whatever subset
    /// is served. The default is simply a full `denoise` (always correct).
    fn corrector_denoise(&mut self, x_t: &[f32], ctx: &StepContext) -> DenoiseResult {
        self.denoise(x_t, ctx)
    }

    /// Logical working set (the paper's Memory column attribution).
    fn working_set_bytes(&self, ds: &Dataset) -> u64 {
        ds.bytes()
    }
}

/// Factory-friendly method taxonomy (CLI / config / bench names).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DenoiserKind {
    Optimal,
    Wiener,
    Kamb,
    /// PCA baseline with biased WSS (the published configuration)
    Pca,
    /// PCA with unbiased streaming softmax ("PCA (Unbiased)")
    PcaUnbiased,
    /// GoldDiff over plain pixel-space logits (= GoldDiff-on-Optimal)
    GoldDiff,
    /// GoldDiff over the PCA subspace weighting (the paper's primary config)
    GoldDiffPca,
    /// GoldDiff + biased WSS (Tab. 6 ablation arm)
    GoldDiffWss,
    /// GoldDiff wrapped around Kamb (Tab. 5)
    GoldDiffKamb,
}

impl DenoiserKind {
    pub fn parse(s: &str) -> Option<DenoiserKind> {
        Some(match s {
            "optimal" => DenoiserKind::Optimal,
            "wiener" => DenoiserKind::Wiener,
            "kamb" => DenoiserKind::Kamb,
            "pca" => DenoiserKind::Pca,
            "pca-unbiased" => DenoiserKind::PcaUnbiased,
            "golden" | "golddiff" => DenoiserKind::GoldDiff,
            "golddiff-pca" => DenoiserKind::GoldDiffPca,
            "golddiff-wss" => DenoiserKind::GoldDiffWss,
            "golddiff-kamb" => DenoiserKind::GoldDiffKamb,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DenoiserKind::Optimal => "optimal",
            DenoiserKind::Wiener => "wiener",
            DenoiserKind::Kamb => "kamb",
            DenoiserKind::Pca => "pca",
            DenoiserKind::PcaUnbiased => "pca-unbiased",
            DenoiserKind::GoldDiff => "golddiff",
            DenoiserKind::GoldDiffPca => "golddiff-pca",
            DenoiserKind::GoldDiffWss => "golddiff-wss",
            DenoiserKind::GoldDiffKamb => "golddiff-kamb",
        }
    }

    pub fn all() -> &'static [DenoiserKind] {
        &[
            DenoiserKind::Optimal,
            DenoiserKind::Wiener,
            DenoiserKind::Kamb,
            DenoiserKind::Pca,
            DenoiserKind::PcaUnbiased,
            DenoiserKind::GoldDiff,
            DenoiserKind::GoldDiffPca,
            DenoiserKind::GoldDiffWss,
            DenoiserKind::GoldDiffKamb,
        ]
    }

    /// Build a denoiser for a dataset with the paper's default budgets.
    pub fn build(&self, ds: &Dataset, sched: &NoiseSchedule) -> Box<dyn Denoiser> {
        use golddiff::{BaseWeighting, GoldDiff};
        match self {
            DenoiserKind::Optimal => Box::new(optimal::OptimalDenoiser::new()),
            DenoiserKind::Wiener => Box::new(wiener::WienerDenoiser::new(ds)),
            DenoiserKind::Kamb => Box::new(kamb::KambDenoiser::new(ds)),
            DenoiserKind::Pca => Box::new(pca::PcaDenoiser::new(ds, false)),
            DenoiserKind::PcaUnbiased => Box::new(pca::PcaDenoiser::new(ds, true)),
            DenoiserKind::GoldDiff => {
                Box::new(GoldDiff::paper_defaults(ds, sched, BaseWeighting::Golden))
            }
            DenoiserKind::GoldDiffPca => Box::new(GoldDiff::paper_defaults(
                ds,
                sched,
                BaseWeighting::PcaSubspace { unbiased: true },
            )),
            DenoiserKind::GoldDiffWss => Box::new(GoldDiff::paper_defaults(
                ds,
                sched,
                BaseWeighting::PcaSubspace { unbiased: false },
            )),
            DenoiserKind::GoldDiffKamb => {
                Box::new(GoldDiff::paper_defaults(ds, sched, BaseWeighting::Kamb))
            }
        }
    }
}

/// Squared distance between two vectors.
#[inline]
pub(crate) fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        acc += d * d;
    }
    acc
}

/// Descale x_t into q = x_t/√ᾱ.
pub(crate) fn descale(x_t: &[f32], alpha_bar: f32) -> Vec<f32> {
    let inv = 1.0 / alpha_bar.max(1e-12).sqrt();
    x_t.iter().map(|&v| v * inv).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for &k in DenoiserKind::all() {
            assert_eq!(DenoiserKind::parse(k.name()), Some(k));
        }
        assert_eq!(DenoiserKind::parse("bogus"), None);
    }

    #[test]
    fn sqdist_basics() {
        assert_eq!(sqdist(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(sqdist(&[1.0], &[1.0]), 0.0);
    }

    #[test]
    fn descale_divides_by_sqrt_alpha() {
        let q = descale(&[2.0, 4.0], 0.25);
        assert_eq!(q, vec![4.0, 8.0]);
    }
}
