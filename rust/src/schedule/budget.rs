//! The paper's *Counter-Monotonic Schedule* (Sec. 3.4): retrieval scope m_t
//! grows and aggregation budget k_t shrinks as noise decreases.
//!
//!   m_t = ⌊ m_min + (m_max - m_min) · (1 - g(σ_t)) ⌋     (Eq. 4)
//!   k_t = ⌊ k_min + (k_max - k_min) ·      g(σ_t)  ⌋     (Eq. 6)
//!
//! Defaults follow Sec. 4.1: m_min = k_max = N/10, m_max = N/4,
//! k_min = N/20. XLA executables need static shapes, so both budgets are
//! rounded *up* to the bucket ladder compiled by aot.py; the mask handles
//! the padding.

use super::noise::NoiseSchedule;

/// Per-step retrieval/aggregation budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepBudget {
    /// coarse candidate pool size m_t (exact, pre-bucketing)
    pub m: usize,
    /// golden subset size k_t (exact, pre-bucketing)
    pub k: usize,
    /// m_t rounded up to a compiled bucket
    pub m_bucket: usize,
    /// k_t rounded up to a compiled bucket
    pub k_bucket: usize,
}

/// Schedule generator bound to a dataset size and bucket ladder.
#[derive(Debug, Clone)]
pub struct BudgetSchedule {
    pub n: usize,
    pub m_min: usize,
    pub m_max: usize,
    pub k_min: usize,
    pub k_max: usize,
    buckets: Vec<usize>, // ascending compiled bucket ladder
}

impl BudgetSchedule {
    /// Paper defaults: m_min = k_max = N/10, m_max = N/4, k_min = N/20.
    pub fn paper_defaults(n: usize, buckets: &[usize]) -> BudgetSchedule {
        BudgetSchedule::new(n, n / 10, n / 4, n / 20, n / 10, buckets)
    }

    pub fn new(
        n: usize,
        m_min: usize,
        m_max: usize,
        k_min: usize,
        k_max: usize,
        buckets: &[usize],
    ) -> BudgetSchedule {
        assert!(m_min <= m_max, "m_min {m_min} > m_max {m_max}");
        assert!(k_min <= k_max, "k_min {k_min} > k_max {k_max}");
        assert!(k_max <= m_max, "k_max must fit in the candidate pool");
        let mut buckets = buckets.to_vec();
        buckets.sort_unstable();
        buckets.dedup();
        assert!(!buckets.is_empty());
        BudgetSchedule {
            n,
            m_min: m_min.max(1),
            m_max: m_max.max(1),
            k_min: k_min.max(1),
            k_max: k_max.max(1),
            buckets,
        }
    }

    /// Round a budget up to the nearest compiled bucket (or the largest
    /// bucket when it exceeds the ladder — mask covers the rest).
    pub fn to_bucket(&self, want: usize) -> usize {
        for &b in &self.buckets {
            if b >= want {
                return b;
            }
        }
        *self.buckets.last().unwrap()
    }

    /// Budgets at sampling point i of `sched` (Eqs. 4 & 6).
    pub fn at(&self, sched: &NoiseSchedule, i: usize) -> StepBudget {
        let g = sched.g(i) as f64;
        let m = (self.m_min as f64 + (self.m_max - self.m_min) as f64 * (1.0 - g)).floor()
            as usize;
        let k = (self.k_min as f64 + (self.k_max - self.k_min) as f64 * g).floor() as usize;
        let m = m.clamp(1, self.n);
        let k = k.clamp(1, m);
        StepBudget {
            m,
            k,
            m_bucket: self.to_bucket(m),
            k_bucket: self.to_bucket(k),
        }
    }

    /// Full trajectory of budgets for a schedule.
    pub fn trajectory(&self, sched: &NoiseSchedule) -> Vec<StepBudget> {
        (0..sched.steps).map(|i| self.at(sched, i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::noise::ScheduleKind;
    use crate::util::prop::{forall, gen};

    const BUCKETS: &[usize] = &[32, 128, 512, 2048, 8192, 16384];

    fn sched() -> NoiseSchedule {
        NoiseSchedule::new(ScheduleKind::DdpmLinear, 10)
    }

    #[test]
    fn counter_monotonic() {
        let b = BudgetSchedule::paper_defaults(10_000, BUCKETS);
        let traj = b.trajectory(&sched());
        for w in traj.windows(2) {
            assert!(w[1].m >= w[0].m, "m must grow as noise decreases");
            assert!(w[1].k <= w[0].k, "k must shrink as noise decreases");
        }
        // endpoints approach the configured extremes (g(σ) does not reach
        // exactly {0,1} on a finite schedule, so allow a 10% band)
        let k_range = b.k_max - b.k_min;
        let m_range = b.m_max - b.m_min;
        assert!(traj[0].k >= b.k_max - k_range / 10);
        assert!(traj.last().unwrap().k <= b.k_min + k_range / 10);
        assert!(traj.last().unwrap().m >= b.m_max - m_range / 10);
        assert!(traj[0].m <= b.m_min + m_range / 10);
    }

    #[test]
    fn paper_default_ratios() {
        let b = BudgetSchedule::paper_defaults(50_000, BUCKETS);
        assert_eq!(b.m_min, 5_000);
        assert_eq!(b.m_max, 12_500);
        assert_eq!(b.k_min, 2_500);
        assert_eq!(b.k_max, 5_000);
    }

    #[test]
    fn bucket_rounding_covers_budget() {
        let b = BudgetSchedule::paper_defaults(10_000, BUCKETS);
        for i in 0..10 {
            let s = b.at(&sched(), i);
            assert!(s.k_bucket >= s.k || s.k_bucket == *BUCKETS.last().unwrap());
            assert!(s.m_bucket >= s.m || s.m_bucket == *BUCKETS.last().unwrap());
            assert!(BUCKETS.contains(&s.k_bucket));
        }
    }

    #[test]
    fn k_never_exceeds_m() {
        forall(17, 200, |rng| {
            let n = gen::usize_in(rng, 100, 100_000);
            let b = BudgetSchedule::paper_defaults(n, BUCKETS);
            let steps = gen::usize_in(rng, 2, 100);
            let sched = NoiseSchedule::new(ScheduleKind::Cosine, steps);
            for i in 0..steps {
                let s = b.at(&sched, i);
                crate::prop_assert!(s.k <= s.m, "k {} > m {} at step {i} n {n}", s.k, s.m);
                crate::prop_assert!(s.k >= 1 && s.m <= n, "bounds violated");
            }
            Ok(())
        });
    }

    #[test]
    fn budgets_within_configured_range() {
        forall(23, 100, |rng| {
            let n = gen::usize_in(rng, 1_000, 60_000);
            let b = BudgetSchedule::paper_defaults(n, BUCKETS);
            let sched = NoiseSchedule::new(ScheduleKind::EdmVp, 10);
            for i in 0..10 {
                let s = b.at(&sched, i);
                crate::prop_assert!(
                    s.m >= b.m_min && s.m <= b.m_max,
                    "m {} outside [{}, {}]",
                    s.m,
                    b.m_min,
                    b.m_max
                );
                crate::prop_assert!(
                    s.k >= b.k_min && s.k <= b.k_max,
                    "k {} outside [{}, {}]",
                    s.k,
                    b.k_min,
                    b.k_max
                );
            }
            Ok(())
        });
    }
}
