//! Budgeted step allocation: spend a fixed tick budget where the golden
//! support churns fastest, coast everywhere else.
//!
//! Every engine tick costs a coarse screen + masked refine, so sample
//! latency is linear in the number of placed sampling points. But the
//! trajectory's support-overlap statistic shows the golden subset changes
//! at a very uneven rate along the schedule — most grid points refine a
//! support that barely moved. The allocator keeps the full grid as the
//! *noise parameterisation* (budgets `m/k` per placed point are issued by
//! `BudgetSchedule` unchanged) and simply chooses **which** grid points get
//! a tick:
//!
//! * Gaussian-prefix points (`step < gauss_switch`) are always placed —
//!   they are served closed-form with zero screens, so coasting through
//!   them costs nothing and keeps the hand-off state accurate.
//! * Both endpoints are always placed: point 0 because the trajectory
//!   starts there, point `steps−1` because the final contraction to the
//!   manifold is where precision retrieval pays.
//! * The remaining budget goes to the retrieval-segment points with the
//!   highest churn priority, greedily — which makes plans **nested**: the
//!   plan for budget b is a subset of the plan for budget b+1.
//!
//! Between two placed points the solver jumps directly (the DDIM map takes
//! any ᾱ → ᾱ' pair), and the warm-start layer seeds the next screen from
//! the latest recorded golden subsets, so a coasted gap is crossed with a
//! warm (still exactness-preserving) screen rather than a cold one.

use std::collections::HashSet;

use super::noise::NoiseSchedule;

/// The set of grid points a trajectory actually ticks at.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StepPlan {
    /// placed sampling points (grid indices), strictly ascending; always
    /// contains 0 and `steps − 1`
    pub placed: Vec<usize>,
    /// the full grid length the plan was cut from
    pub steps: usize,
}

impl StepPlan {
    /// The trivial plan: every grid point is placed (budget off).
    pub fn full(steps: usize) -> StepPlan {
        StepPlan {
            placed: (0..steps).collect(),
            steps,
        }
    }

    /// Place `budget` retrieval-segment ticks by churn priority (see the
    /// module docs). `budget == 0` or a budget covering the whole segment
    /// yields the full grid; the gauss prefix `0..gauss_switch` is always
    /// placed for free. `churn` must have one entry per grid point.
    pub fn budgeted(
        sched: &NoiseSchedule,
        budget: usize,
        gauss_switch: usize,
        churn: &[f64],
    ) -> StepPlan {
        let steps = sched.steps;
        assert_eq!(churn.len(), steps, "one churn entry per grid point");
        let switch = gauss_switch.min(steps);
        let seg_len = steps - switch;
        if budget == 0 || budget >= seg_len {
            return StepPlan::full(steps);
        }
        // endpoints are mandatory wherever they fall in the segment
        let mut chosen: Vec<usize> = Vec::new();
        if switch == 0 {
            chosen.push(0);
        }
        if steps - 1 >= switch && !chosen.contains(&(steps - 1)) {
            chosen.push(steps - 1);
        }
        let target = budget.max(chosen.len()).min(seg_len);
        // greedy churn-priority fill (deterministic tie-break on index);
        // a fixed ranking makes plans nested as the budget grows
        let mut ranked: Vec<usize> = (switch..steps).filter(|i| !chosen.contains(i)).collect();
        ranked.sort_by(|&a, &b| {
            churn[b]
                .partial_cmp(&churn[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        chosen.extend(ranked.into_iter().take(target - chosen.len()));
        let mut placed: Vec<usize> = (0..switch).chain(chosen).collect();
        placed.sort_unstable();
        placed.dedup();
        StepPlan { placed, steps }
    }

    /// Is every grid point placed (the byte-identical default)?
    pub fn is_full(&self) -> bool {
        self.placed.len() == self.steps
    }

    /// Number of placed points.
    pub fn len(&self) -> usize {
        self.placed.len()
    }

    pub fn is_empty(&self) -> bool {
        self.placed.is_empty()
    }

    /// The grid target of the tick at plan position `pos`: the next placed
    /// point, or `steps` (the terminal clean point, ᾱ = 1) after the last.
    pub fn target_of(&self, pos: usize) -> usize {
        self.placed
            .get(pos + 1)
            .copied()
            .unwrap_or(self.steps)
    }
}

/// The measured churn signal: per-step support overlap between consecutive
/// golden subsets, as a change fraction `1 − |S_i ∩ S_{i−1}| / |S_i|`.
/// Index 0 (no predecessor) counts as full churn — the first screen is
/// always cold.
pub fn churn_from_subsets(subsets: &[Vec<u32>]) -> Vec<f64> {
    let mut churn = Vec::with_capacity(subsets.len());
    for (i, s) in subsets.iter().enumerate() {
        if i == 0 || s.is_empty() {
            churn.push(1.0);
            continue;
        }
        let prev: HashSet<u32> = subsets[i - 1].iter().copied().collect();
        let overlap = s.iter().filter(|r| prev.contains(r)).count();
        churn.push(1.0 - overlap as f64 / s.len() as f64);
    }
    churn
}

/// The schedule-only churn prior used when no pilot trajectory exists (the
/// engine's default): the support moves fastest where the noise level does,
/// so weight each point by the local ᾱ motion `g(i−1) − g(i+1)` (one-sided
/// at the endpoints). Strictly positive since g is strictly decreasing.
pub fn churn_prior(sched: &NoiseSchedule) -> Vec<f64> {
    let steps = sched.steps;
    (0..steps)
        .map(|i| {
            let lo = i.saturating_sub(1);
            let hi = (i + 1).min(steps - 1);
            if hi == lo {
                1.0
            } else {
                (sched.g(lo) - sched.g(hi)) as f64
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::noise::ScheduleKind;

    fn sched(steps: usize) -> NoiseSchedule {
        NoiseSchedule::new(ScheduleKind::DdpmLinear, steps)
    }

    #[test]
    fn zero_budget_and_saturating_budget_yield_the_full_grid() {
        let s = sched(10);
        let churn = churn_prior(&s);
        assert_eq!(StepPlan::budgeted(&s, 0, 0, &churn), StepPlan::full(10));
        for b in [10usize, 11, 100] {
            assert_eq!(StepPlan::budgeted(&s, b, 0, &churn), StepPlan::full(10));
        }
        // with a gauss prefix the budget only has to cover the segment
        assert_eq!(StepPlan::budgeted(&s, 7, 3, &churn), StepPlan::full(10));
        assert!(StepPlan::full(10).is_full());
        assert_eq!(StepPlan::full(10).target_of(9), 10);
        assert_eq!(StepPlan::full(10).target_of(4), 5);
    }

    #[test]
    fn budget_is_exactly_spent_and_endpoints_always_placed() {
        let s = sched(12);
        let churn = churn_prior(&s);
        for switch in [0usize, 3, 5] {
            let seg = s.steps - switch;
            for budget in 1..seg {
                let plan = StepPlan::budgeted(&s, budget, switch, &churn);
                let seg_placed = plan.placed.iter().filter(|&&p| p >= switch).count();
                // the mandatory endpoints can push a budget of 1 up to 2
                let want = budget.max(if switch == 0 { 2 } else { 1 }).min(seg);
                assert_eq!(seg_placed, want, "switch={switch} budget={budget}");
                assert_eq!(plan.placed[0], 0, "start must be placed");
                assert_eq!(
                    *plan.placed.last().unwrap(),
                    s.steps - 1,
                    "terminal must be placed"
                );
                // the whole gauss prefix rides for free
                for p in 0..switch {
                    assert!(plan.placed.contains(&p), "prefix point {p} missing");
                }
                // strictly ascending, in range
                assert!(plan.placed.windows(2).all(|w| w[0] < w[1]));
                assert!(plan.placed.iter().all(|&p| p < s.steps));
            }
        }
    }

    #[test]
    fn plans_are_nested_as_the_budget_grows() {
        for kind in [ScheduleKind::DdpmLinear, ScheduleKind::Cosine] {
            let s = NoiseSchedule::new(kind, 16);
            for churn in [churn_prior(&s), vec![0.5; 16]] {
                for switch in [0usize, 4] {
                    let mut prev: Option<StepPlan> = None;
                    for budget in 1..(s.steps - switch) {
                        let plan = StepPlan::budgeted(&s, budget, switch, &churn);
                        if let Some(p) = &prev {
                            for pt in &p.placed {
                                assert!(
                                    plan.placed.contains(pt),
                                    "{kind:?} switch={switch} budget={budget} dropped {pt}"
                                );
                            }
                        }
                        prev = Some(plan);
                    }
                }
            }
        }
    }

    #[test]
    fn high_churn_points_are_placed_first() {
        let s = sched(10);
        let mut churn = vec![0.0f64; 10];
        churn[4] = 1.0;
        churn[7] = 0.9;
        let plan = StepPlan::budgeted(&s, 4, 0, &churn);
        // endpoints + the two churn spikes
        assert_eq!(plan.placed, vec![0, 4, 7, 9]);
    }

    #[test]
    fn target_of_jumps_to_the_next_placed_point() {
        let s = sched(10);
        let mut churn = vec![0.0f64; 10];
        churn[5] = 1.0;
        let plan = StepPlan::budgeted(&s, 3, 0, &churn);
        assert_eq!(plan.placed, vec![0, 5, 9]);
        assert_eq!(plan.target_of(0), 5);
        assert_eq!(plan.target_of(1), 9);
        assert_eq!(plan.target_of(2), 10, "last tick lands on ᾱ = 1");
    }

    #[test]
    fn churn_from_subsets_measures_overlap() {
        let subsets = vec![
            vec![1u32, 2, 3, 4],
            vec![1, 2, 3, 4],
            vec![1, 2, 5, 6],
            vec![7, 8],
        ];
        let churn = churn_from_subsets(&subsets);
        assert_eq!(churn, vec![1.0, 0.0, 0.5, 1.0]);
        assert_eq!(churn_from_subsets(&[]), Vec::<f64>::new());
    }

    #[test]
    fn churn_prior_is_positive_and_deterministic() {
        for kind in [
            ScheduleKind::DdpmLinear,
            ScheduleKind::Cosine,
            ScheduleKind::EdmVp,
            ScheduleKind::EdmVe,
        ] {
            let s = NoiseSchedule::new(kind, 10);
            let c = churn_prior(&s);
            assert_eq!(c.len(), 10);
            assert!(c.iter().all(|&v| v > 0.0), "{kind:?}");
            assert_eq!(c, churn_prior(&s));
        }
    }
}
