//! Diffusion noise schedules and the paper's counter-monotonic retrieval /
//! aggregation budget schedules (Sec. 3.4).

pub mod budget;
pub mod noise;

pub use budget::{BudgetSchedule, StepBudget};
pub use noise::{NoiseSchedule, ScheduleKind};
