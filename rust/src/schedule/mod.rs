//! Diffusion noise schedules, the paper's counter-monotonic retrieval /
//! aggregation budget schedules (Sec. 3.4), and the budgeted step
//! allocator that decides which grid points get a tick at all.

pub mod budget;
pub mod noise;
pub mod steps;

pub use budget::{BudgetSchedule, StepBudget};
pub use noise::{NoiseSchedule, ScheduleKind};
pub use steps::{churn_from_subsets, churn_prior, StepPlan};
