//! Noise schedules: cumulative signal level ᾱ_t for the DDPM convention
//! x_t = √ᾱ_t x₀ + √(1-ᾱ_t) ε, plus EDM-VP / EDM-VE parameterisations
//! (Karras et al. 2022) used by Table 4's "diverse neural denoisers" rows.
//!
//! All schedules expose the same interface: a descending list of timesteps
//! (t = T-1 … 0 over `steps` sampling points, as in 10-step DDIM) with
//! `alpha_bar(i)` the signal level at sampling point i and the derived
//! noise-to-signal ratio σ_t² = (1-ᾱ)/ᾱ used in the analytical logits.

/// Which schedule family to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleKind {
    /// DDPM linear-β (Ho et al. 2020), T=1000 reference grid.
    DdpmLinear,
    /// Cosine ᾱ (Nichol & Dhariwal).
    Cosine,
    /// EDM variance-preserving parameterisation.
    EdmVp,
    /// EDM variance-exploding parameterisation (σ ∈ [σ_min, σ_max]).
    EdmVe,
}

impl ScheduleKind {
    pub fn parse(s: &str) -> Option<ScheduleKind> {
        match s {
            "ddpm" | "ddpm-linear" => Some(ScheduleKind::DdpmLinear),
            "cosine" => Some(ScheduleKind::Cosine),
            "edm-vp" => Some(ScheduleKind::EdmVp),
            "edm-ve" => Some(ScheduleKind::EdmVe),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScheduleKind::DdpmLinear => "ddpm",
            ScheduleKind::Cosine => "cosine",
            ScheduleKind::EdmVp => "edm-vp",
            ScheduleKind::EdmVe => "edm-ve",
        }
    }
}

/// A sampled schedule: `steps` points, index 0 = highest noise (start of
/// reverse diffusion), index steps-1 = lowest noise (end).
#[derive(Debug, Clone)]
pub struct NoiseSchedule {
    pub kind: ScheduleKind,
    pub steps: usize,
    alpha_bars: Vec<f32>, // per sampling point, ascending signal
}

const T_REF: usize = 1000;

impl NoiseSchedule {
    pub fn new(kind: ScheduleKind, steps: usize) -> NoiseSchedule {
        assert!(steps >= 1);
        // Reference ᾱ grid over T_REF steps, then strided DDIM-style.
        let grid: Vec<f64> = match kind {
            ScheduleKind::DdpmLinear => {
                let beta0 = 1e-4;
                let beta1 = 0.02;
                let mut acc = 1.0f64;
                (0..T_REF)
                    .map(|t| {
                        let beta = beta0 + (beta1 - beta0) * t as f64 / (T_REF - 1) as f64;
                        acc *= 1.0 - beta;
                        acc
                    })
                    .collect()
            }
            ScheduleKind::Cosine => {
                let s = 0.008;
                let f = |t: f64| ((t + s) / (1.0 + s) * std::f64::consts::FRAC_PI_2).cos().powi(2);
                (0..T_REF)
                    .map(|t| {
                        let x = (t as f64 + 1.0) / T_REF as f64;
                        (f(x) / f(0.0)).clamp(1e-6, 0.99999)
                    })
                    .collect()
            }
            ScheduleKind::EdmVp => {
                // VP: sigma(t)^2 = exp(0.5 beta_d t^2 + beta_min t) - 1,
                // alpha_bar = 1/(1+sigma^2).
                let beta_d = 19.9;
                let beta_min = 0.1;
                (0..T_REF)
                    .map(|i| {
                        let t = 1e-3 + (1.0 - 1e-3) * (i as f64 + 1.0) / T_REF as f64;
                        let sigma2 = (0.5 * beta_d * t * t + beta_min * t).exp() - 1.0;
                        (1.0 / (1.0 + sigma2)).clamp(1e-6, 0.99999)
                    })
                    .collect()
            }
            ScheduleKind::EdmVe => {
                // VE: sigma geometric in [0.02, 100]; map to alpha_bar via
                // the scaled-query equivalence alpha = 1/(1+sigma^2).
                let (s_min, s_max) = (0.02f64, 100.0f64);
                (0..T_REF)
                    .map(|i| {
                        let u = (i as f64 + 1.0) / T_REF as f64;
                        let sigma = s_min * (s_max / s_min).powf(u);
                        (1.0 / (1.0 + sigma * sigma)).clamp(1e-9, 0.99999)
                    })
                    .collect()
            }
        };

        // DDIM stride: pick `steps` indices from the reference grid,
        // descending in t (ascending in signal along sampling order).
        let mut alpha_bars = Vec::with_capacity(steps);
        for i in 0..steps {
            // i = 0 -> deepest noise (t = T-1); i = steps-1 -> t = 0
            let frac = if steps == 1 {
                1.0
            } else {
                1.0 - i as f64 / (steps - 1) as f64
            };
            let idx = ((T_REF - 1) as f64 * frac).round() as usize;
            alpha_bars.push(grid[idx] as f32);
        }
        NoiseSchedule {
            kind,
            steps,
            alpha_bars,
        }
    }

    /// Signal level ᾱ at sampling point i (0 = highest noise).
    pub fn alpha_bar(&self, i: usize) -> f32 {
        self.alpha_bars[i]
    }

    /// ᾱ for the *next* sampling point (i+1), 1.0 at the terminal step.
    pub fn alpha_prev(&self, i: usize) -> f32 {
        if i + 1 < self.steps {
            self.alpha_bars[i + 1]
        } else {
            1.0
        }
    }

    /// Noise-to-signal ratio σ_t² = (1-ᾱ)/ᾱ.
    pub fn sigma2(&self, i: usize) -> f32 {
        let a = self.alpha_bar(i);
        (1.0 - a) / a
    }

    /// Normalised noise level g(σ_t) ∈ [0,1] used by the budget schedules
    /// (Eqs. 4 & 6): g = σ²/(1+σ²) = 1-ᾱ. 1 at pure noise, 0 at data.
    pub fn g(&self, i: usize) -> f32 {
        1.0 - self.alpha_bar(i)
    }

    /// The analytical-logit scale 1/(2σ_t²).
    pub fn logit_scale(&self, i: usize) -> f32 {
        1.0 / (2.0 * self.sigma2(i)).max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_is_monotone_increasing_along_sampling() {
        for kind in [
            ScheduleKind::DdpmLinear,
            ScheduleKind::Cosine,
            ScheduleKind::EdmVp,
            ScheduleKind::EdmVe,
        ] {
            let s = NoiseSchedule::new(kind, 10);
            for i in 1..s.steps {
                assert!(
                    s.alpha_bar(i) > s.alpha_bar(i - 1),
                    "{kind:?} not monotone at {i}"
                );
            }
            assert!(s.alpha_bar(0) < 0.1, "{kind:?} should start noisy");
            assert!(s.alpha_bar(s.steps - 1) > 0.5, "{kind:?} should end clean");
        }
    }

    #[test]
    fn g_is_in_unit_interval_and_decreasing() {
        let s = NoiseSchedule::new(ScheduleKind::DdpmLinear, 25);
        for i in 0..s.steps {
            assert!((0.0..=1.0).contains(&s.g(i)));
            if i > 0 {
                assert!(s.g(i) < s.g(i - 1));
            }
        }
    }

    #[test]
    fn alpha_prev_terminal_is_one() {
        let s = NoiseSchedule::new(ScheduleKind::Cosine, 10);
        assert_eq!(s.alpha_prev(9), 1.0);
        assert_eq!(s.alpha_prev(3), s.alpha_bar(4));
    }

    #[test]
    fn sigma2_matches_alpha() {
        let s = NoiseSchedule::new(ScheduleKind::EdmVp, 10);
        for i in 0..10 {
            let a = s.alpha_bar(i);
            assert!((s.sigma2(i) - (1.0 - a) / a).abs() < 1e-6);
        }
    }

    #[test]
    fn ve_spans_the_karras_sigma_range() {
        // VE: sigma in [0.02, 100] geometric — huge dynamic range, with a
        // much cleaner terminal step than VP.
        let ve = NoiseSchedule::new(ScheduleKind::EdmVe, 10);
        assert!(ve.sigma2(0) > 1e3);
        assert!(ve.sigma2(9) < 1e-2);
    }

    #[test]
    fn parse_names_roundtrip() {
        for kind in [
            ScheduleKind::DdpmLinear,
            ScheduleKind::Cosine,
            ScheduleKind::EdmVp,
            ScheduleKind::EdmVe,
        ] {
            assert_eq!(ScheduleKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ScheduleKind::parse("bogus"), None);
    }
}
