//! Tiny declarative CLI argument parser (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and subcommand dispatch; generates usage text from registered options.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding program name / subcommand).
    pub fn parse(raw: &[String]) -> Args {
        let mut options = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    options.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    options.insert(stripped.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    flags.push(stripped.to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Args {
            options,
            flags,
            positional,
        }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.get(key) == Some("true")
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Subcommand registry with usage rendering.
pub struct Cli {
    pub name: &'static str,
    pub about: &'static str,
    commands: Vec<(&'static str, &'static str)>,
}

impl Cli {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Cli {
            name,
            about,
            commands: Vec::new(),
        }
    }

    pub fn command(mut self, cmd: &'static str, help: &'static str) -> Self {
        self.commands.push((cmd, help));
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n", self.name, self.about, self.name);
        for (cmd, help) in &self.commands {
            out.push_str(&format!("  {cmd:<18} {help}\n"));
        }
        out
    }

    /// Split argv into (subcommand, args). Returns None when help is needed.
    pub fn dispatch(&self, argv: &[String]) -> Option<(String, Args)> {
        if argv.is_empty() || argv[0] == "help" || argv[0] == "--help" || argv[0] == "-h" {
            return None;
        }
        let cmd = argv[0].clone();
        if !self.commands.iter().any(|(c, _)| *c == cmd) {
            return None;
        }
        Some((cmd, Args::parse(&argv[1..])))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = Args::parse(&sv(&["--k", "8", "--preset=cifar-sim", "pos1"]));
        assert_eq!(a.usize_or("k", 0), 8);
        assert_eq!(a.get("preset"), Some("cifar-sim"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn flags_without_values() {
        let a = Args::parse(&sv(&["--verbose", "--n", "5"]));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.usize_or("n", 0), 5);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&sv(&["--all"]));
        assert!(a.flag("all"));
    }

    #[test]
    fn dispatch_known_and_unknown() {
        let cli = Cli::new("golddiff", "test").command("serve", "run server");
        assert!(cli.dispatch(&sv(&["serve", "--port", "8080"])).is_some());
        assert!(cli.dispatch(&sv(&["nope"])).is_none());
        assert!(cli.dispatch(&sv(&[])).is_none());
        assert!(cli.usage().contains("serve"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&[]));
        assert_eq!(a.f64_or("lr", 0.5), 0.5);
        assert_eq!(a.get_or("preset", "moons"), "moons");
    }
}
