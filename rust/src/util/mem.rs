//! Process-memory probes (peak RSS) — the "Memory (GB)" column of the
//! paper's Tables 2 and 7 — plus a lightweight logical-bytes tracker for
//! attributing working-set size to a single denoiser.

use std::fs;
use std::sync::atomic::{AtomicU64, Ordering};

/// Peak resident set size of this process in bytes (VmHWM from
/// /proc/self/status). Returns 0 on non-Linux or parse failure.
pub fn peak_rss_bytes() -> u64 {
    read_status_kib("VmHWM:").map(|k| k * 1024).unwrap_or(0)
}

/// Current resident set size in bytes.
pub fn current_rss_bytes() -> u64 {
    read_status_kib("VmRSS:").map(|k| k * 1024).unwrap_or(0)
}

fn read_status_kib(field: &str) -> Option<u64> {
    let status = fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix(field) {
            let kib: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kib);
        }
    }
    None
}

/// Logical working-set tracker: denoisers report the buffers they allocate
/// so the per-method memory column is attributable (process RSS is shared
/// across methods within one bench run).
#[derive(Debug, Default)]
pub struct WorkingSet {
    current: AtomicU64,
    peak: AtomicU64,
}

impl WorkingSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc(&self, bytes: u64) {
        let now = self.current.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    pub fn free(&self, bytes: u64) {
        self.current.fetch_sub(bytes.min(self.current.load(Ordering::Relaxed)), Ordering::Relaxed);
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn current_bytes(&self) -> u64 {
        self.current.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.current.store(0, Ordering::Relaxed);
        self.peak.store(0, Ordering::Relaxed);
    }
}

pub fn gib(bytes: u64) -> f64 {
    bytes as f64 / (1u64 << 30) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_is_positive_on_linux() {
        assert!(peak_rss_bytes() > 0);
        assert!(current_rss_bytes() > 0);
    }

    #[test]
    fn working_set_tracks_peak() {
        let ws = WorkingSet::new();
        ws.alloc(100);
        ws.alloc(50);
        ws.free(120);
        ws.alloc(10);
        assert_eq!(ws.peak_bytes(), 150);
        assert!(ws.current_bytes() <= 40);
        ws.reset();
        assert_eq!(ws.peak_bytes(), 0);
    }

    #[test]
    fn gib_conversion() {
        assert!((gib(1 << 30) - 1.0).abs() < 1e-12);
    }
}
