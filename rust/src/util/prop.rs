//! Hand-rolled property-testing helper (proptest is not available offline).
//!
//! `forall` runs a property over `n` generated cases from a seeded PCG
//! stream and, on failure, reports the failing case number and seed so the
//! exact case can be replayed deterministically. Generators are plain
//! closures over `Pcg64`, composed with ordinary rust code.

use super::rng::Pcg64;

/// Run `prop(case_rng)` for `cases` deterministic cases derived from `seed`.
/// Panics with the replay seed on the first failing case.
pub fn forall<F>(seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Pcg64) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(case as u64);
        let mut rng = Pcg64::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at case {case}/{cases} (replay seed {case_seed}): {msg}");
        }
    }
}

/// Generator helpers.
pub mod gen {
    use super::Pcg64;

    pub fn usize_in(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    pub fn f32_in(rng: &mut Pcg64, lo: f32, hi: f32) -> f32 {
        lo + rng.f32() * (hi - lo)
    }

    pub fn vec_normal(rng: &mut Pcg64, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.normal() * scale).collect()
    }

    /// Power-of-two in [lo, hi].
    pub fn pow2_in(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
        let lo_log = lo.next_power_of_two().trailing_zeros();
        let hi_log = hi.next_power_of_two().trailing_zeros();
        1 << usize_in(rng, lo_log as usize, hi_log as usize)
    }
}

/// Assert helper returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(1, 50, |rng| {
            let x = rng.f32();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(2, 50, |rng| {
            let x = rng.f32();
            if x < 0.9 {
                Ok(())
            } else {
                Err("too big".to_string())
            }
        });
    }

    #[test]
    fn generators_in_bounds() {
        forall(3, 100, |rng| {
            let n = gen::usize_in(rng, 5, 10);
            prop_assert!((5..=10).contains(&n), "n={n}");
            let p = gen::pow2_in(rng, 8, 64);
            prop_assert!(p.is_power_of_two() && (8..=64).contains(&p), "p={p}");
            Ok(())
        });
    }
}
