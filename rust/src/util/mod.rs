//! Offline-friendly substrates: the registry in this image only carries the
//! `xla` crate and its build deps, so JSON, CLI parsing, RNG, the thread
//! pool, property testing and RSS probing are implemented here instead of
//! pulled from crates.io. Each is small, documented and unit-tested.

pub mod cli;
pub mod crc;
pub mod fault;
pub mod json;
pub mod mem;
pub mod pgm;
pub mod prop;
pub mod rng;
pub mod threadpool;
pub mod timer;
