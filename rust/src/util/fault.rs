//! Deterministic fault injection for the storage I/O seam.
//!
//! A seeded [`FaultInjector`] sits behind `ShardReader` (and therefore every
//! streamed `RowSource` read) and decides, per I/O operation, whether to
//! inject a failure. Faults are drawn from a PCG stream keyed by
//! `GOLDDIFF_FAULT_SEED`, so a given seed + rate reproduces the exact same
//! fault schedule across runs — tests can *prove* the retry / checksum /
//! degrade paths fire and that results stay byte-identical to the no-fault
//! run.
//!
//! Three fault kinds:
//! - **Transient** — the read fails up front with an
//!   `ErrorKind::Interrupted`-style error, before any bytes move. Models
//!   EINTR / dropped NFS handles. Recoverable by retry.
//! - **ShortRead** — the read returns fewer bytes than asked, then errors.
//!   Models truncated reads off a flaky device. Recoverable by retry (the
//!   reader re-seeks).
//! - **BitFlip** — the read "succeeds" but one bit in the returned buffer
//!   is flipped. Models silent media corruption; only the per-section
//!   checksum (store v5+) can catch this. Test-only: `from_env` never
//!   enables it, because without checksums (legacy stores) a flip would be
//!   served as data.
//!
//! Env knobs (read once at source construction):
//! - `GOLDDIFF_FAULT_RATE` — fraction of I/O ops that fault (0 disables).
//! - `GOLDDIFF_FAULT_SEED` — PCG seed for the fault schedule (default 7).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::rng::Pcg64;

/// What a faulted I/O operation does. See the module docs for semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    Transient,
    ShortRead,
    BitFlip,
}

const KIND_TRANSIENT: u32 = 1 << 0;
const KIND_SHORT: u32 = 1 << 1;
const KIND_BITFLIP: u32 = 1 << 2;

/// Seeded, thread-safe fault source. `roll()` is called once per I/O
/// operation; the decision sequence depends only on (seed, call order), so
/// single-threaded readers get a fully reproducible schedule.
pub struct FaultInjector {
    rng: Mutex<Pcg64>,
    rate: f64,
    kinds: u32,
    /// stop injecting after this many faults (0 = unlimited). With
    /// `rate = 1.0` this makes tests exactly deterministic: the first
    /// `limit` ops fault, everything after runs clean.
    limit: u64,
    injected: AtomicU64,
}

impl std::fmt::Debug for FaultInjector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultInjector")
            .field("rate", &self.rate)
            .field("kinds", &self.kinds)
            .field("limit", &self.limit)
            .field("injected", &self.injected.load(Ordering::Relaxed))
            .finish()
    }
}

impl FaultInjector {
    fn new(seed: u64, rate: f64, kinds: u32) -> Self {
        Self {
            rng: Mutex::new(Pcg64::new(seed)),
            rate: rate.clamp(0.0, 1.0),
            kinds,
            limit: 0,
            injected: AtomicU64::new(0),
        }
    }

    /// Recoverable faults only (transient errors + short reads). Safe on
    /// any store version: a retry reproduces the exact bytes, so results
    /// stay byte-identical with or without checksums. This is what
    /// `from_env` constructs.
    pub fn transient(seed: u64, rate: f64) -> Self {
        Self::new(seed, rate, KIND_TRANSIENT | KIND_SHORT)
    }

    /// Silent corruption (bit flips) only. Test-only: requires a v5+ store
    /// whose section checksums turn the flip into a detectable, retryable
    /// failure.
    pub fn bit_flips(seed: u64, rate: f64) -> Self {
        Self::new(seed, rate, KIND_BITFLIP)
    }

    /// Cap the number of injected faults. `rate = 1.0` + `with_limit(n)`
    /// gives a fully deterministic schedule: ops 1..=n fault, the rest
    /// run clean.
    pub fn with_limit(mut self, n: u64) -> Self {
        self.limit = n;
        self
    }

    /// Per-op decision. `Some(kind)` means the caller must inject that
    /// fault into this operation; the injected counter has already been
    /// bumped.
    pub fn roll(&self) -> Option<FaultKind> {
        if self.rate <= 0.0 || self.kinds == 0 {
            return None;
        }
        if self.limit != 0 && self.injected.load(Ordering::Relaxed) >= self.limit {
            return None;
        }
        let mut rng = self.rng.lock().unwrap_or_else(|p| p.into_inner());
        if rng.f64() >= self.rate {
            return None;
        }
        // pick uniformly among the enabled kinds
        let enabled: Vec<FaultKind> = [
            (KIND_TRANSIENT, FaultKind::Transient),
            (KIND_SHORT, FaultKind::ShortRead),
            (KIND_BITFLIP, FaultKind::BitFlip),
        ]
        .iter()
        .filter(|(bit, _)| self.kinds & bit != 0)
        .map(|&(_, k)| k)
        .collect();
        let kind = enabled[rng.below(enabled.len())];
        self.injected.fetch_add(1, Ordering::Relaxed);
        Some(kind)
    }

    /// Flip one pseudo-random bit in `buf` (no-op on an empty buffer).
    pub fn flip_bit(&self, buf: &mut [u8]) {
        if buf.is_empty() {
            return;
        }
        let mut rng = self.rng.lock().unwrap_or_else(|p| p.into_inner());
        let byte = rng.below(buf.len());
        let bit = (rng.next_u32() % 8) as u8;
        buf[byte] ^= 1 << bit;
    }

    /// Total faults injected so far.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Build from `GOLDDIFF_FAULT_RATE` / `GOLDDIFF_FAULT_SEED`, or `None`
    /// when the rate is unset/zero. Only recoverable kinds — running a
    /// whole CI leg under this must leave every byte-equality assertion
    /// intact.
    pub fn from_env() -> Option<Arc<FaultInjector>> {
        let rate = crate::config::env_f64("GOLDDIFF_FAULT_RATE", 0.0);
        if rate <= 0.0 {
            return None;
        }
        let seed = crate::config::env_u64("GOLDDIFF_FAULT_SEED", 7);
        Some(Arc::new(FaultInjector::transient(seed, rate)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_schedule() {
        let a = FaultInjector::transient(11, 0.3);
        let b = FaultInjector::transient(11, 0.3);
        let seq_a: Vec<_> = (0..200).map(|_| a.roll()).collect();
        let seq_b: Vec<_> = (0..200).map(|_| b.roll()).collect();
        assert_eq!(seq_a, seq_b);
        assert_eq!(a.injected(), b.injected());
        assert!(a.injected() > 0, "rate 0.3 over 200 ops must fire");
    }

    #[test]
    fn different_seed_different_schedule() {
        let a = FaultInjector::transient(11, 0.3);
        let b = FaultInjector::transient(12, 0.3);
        let seq_a: Vec<_> = (0..200).map(|_| a.roll()).collect();
        let seq_b: Vec<_> = (0..200).map(|_| b.roll()).collect();
        assert_ne!(seq_a, seq_b);
    }

    #[test]
    fn limit_caps_injection_then_runs_clean() {
        let f = FaultInjector::transient(5, 1.0).with_limit(3);
        let fired: Vec<_> = (0..10).map(|_| f.roll()).collect();
        assert!(fired[..3].iter().all(|k| k.is_some()));
        assert!(fired[3..].iter().all(|k| k.is_none()));
        assert_eq!(f.injected(), 3);
    }

    #[test]
    fn zero_rate_never_fires() {
        let f = FaultInjector::transient(5, 0.0);
        assert!((0..100).all(|_| f.roll().is_none()));
        assert_eq!(f.injected(), 0);
    }

    #[test]
    fn kinds_are_respected() {
        let f = FaultInjector::bit_flips(9, 1.0);
        for _ in 0..50 {
            assert_eq!(f.roll(), Some(FaultKind::BitFlip));
        }
        let f = FaultInjector::transient(9, 1.0);
        for _ in 0..50 {
            let k = f.roll().unwrap();
            assert!(k == FaultKind::Transient || k == FaultKind::ShortRead);
        }
    }

    #[test]
    fn flip_bit_changes_exactly_one_bit() {
        let f = FaultInjector::bit_flips(3, 1.0);
        let clean: Vec<u8> = (0..64u32).map(|i| i as u8).collect();
        let mut buf = clean.clone();
        f.flip_bit(&mut buf);
        let diff_bits: u32 = clean
            .iter()
            .zip(&buf)
            .map(|(a, b)| (a ^ b).count_ones())
            .sum();
        assert_eq!(diff_bits, 1);
    }
}
