//! Tiny PPM/PGM image writer — dumps generated samples as viewable images
//! (the repo's analogue of the paper's qualitative Figs. 4/5 grids).
//!
//! Samples live in [-1, 1] (tanh-bounded synthesis); values are clamped and
//! mapped to 8-bit. Binary P5 (grayscale) / P6 (RGB) formats, zero deps.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

/// Write one flattened [h × w × c] sample (c ∈ {1, 3}) as PGM/PPM.
pub fn write_image(path: &Path, x: &[f32], h: usize, w: usize, c: usize) -> Result<()> {
    anyhow::ensure!(c == 1 || c == 3, "c must be 1 or 3, got {c}");
    anyhow::ensure!(x.len() == h * w * c, "shape mismatch");
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    let magic = if c == 1 { "P5" } else { "P6" };
    write!(out, "{magic}\n{w} {h}\n255\n")?;
    let bytes: Vec<u8> = x.iter().map(|&v| to_u8(v)).collect();
    out.write_all(&bytes)?;
    Ok(())
}

/// Tile a list of equally-shaped samples into one grid image with a 1-px
/// separator, `cols` tiles per row.
pub fn write_grid(
    path: &Path,
    samples: &[Vec<f32>],
    h: usize,
    w: usize,
    c: usize,
    cols: usize,
) -> Result<()> {
    anyhow::ensure!(!samples.is_empty());
    let cols = cols.max(1).min(samples.len());
    let rows = samples.len().div_ceil(cols);
    let gw = cols * (w + 1) - 1;
    let gh = rows * (h + 1) - 1;
    let mut grid = vec![-1.0f32; gh * gw * c]; // separators at black
    for (si, s) in samples.iter().enumerate() {
        let (gr, gc) = (si / cols, si % cols);
        let (oy, ox) = (gr * (h + 1), gc * (w + 1));
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    grid[((oy + y) * gw + ox + x) * c + ch] = s[(y * w + x) * c + ch];
                }
            }
        }
    }
    write_image(path, &grid, gh, gw, c)
}

#[inline]
fn to_u8(v: f32) -> u8 {
    (((v.clamp(-1.0, 1.0) + 1.0) * 0.5) * 255.0).round() as u8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_valid_ppm_header_and_size() {
        let dir = std::env::temp_dir().join("golddiff_pgm_test");
        let path = dir.join("t.ppm");
        let x = vec![0.0f32; 4 * 5 * 3];
        write_image(&path, &x, 4, 5, 3).unwrap();
        let data = std::fs::read(&path).unwrap();
        assert!(data.starts_with(b"P6\n5 4\n255\n"));
        assert_eq!(data.len(), 11 + 4 * 5 * 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn value_mapping_clamps() {
        assert_eq!(to_u8(-2.0), 0);
        assert_eq!(to_u8(-1.0), 0);
        assert_eq!(to_u8(1.0), 255);
        assert_eq!(to_u8(0.0), 128);
    }

    #[test]
    fn grid_tiles_with_separators() {
        let dir = std::env::temp_dir().join("golddiff_pgm_test2");
        let path = dir.join("g.pgm");
        let samples = vec![vec![1.0f32; 4], vec![0.0f32; 4], vec![-1.0f32; 4]];
        write_grid(&path, &samples, 2, 2, 1, 2).unwrap();
        let data = std::fs::read(&path).unwrap();
        // 2 cols, 2 rows -> 5x5 grid
        assert!(data.starts_with(b"P5\n5 5\n255\n"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_shapes() {
        let p = std::env::temp_dir().join("x.pgm");
        assert!(write_image(&p, &[0.0; 4], 2, 2, 2).is_err());
        assert!(write_image(&p, &[0.0; 3], 2, 2, 1).is_err());
    }
}
