//! CRC32 (IEEE 802.3, polynomial `0xEDB88320`) over byte and typed slices.
//!
//! The `.gds` store (v5+) records one checksum per section so readers can
//! verify payloads on first touch and name the corrupt section instead of
//! serving garbage rows. A 256-entry table is built at compile time; the
//! streaming [`Crc32`] form lets callers fold large payloads chunk by chunk
//! without materialising a contiguous byte buffer.

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 {
                (crc >> 1) ^ 0xEDB8_8320
            } else {
                crc >> 1
            };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = make_table();

/// Streaming CRC32: `update` in any chunking, then `finish`.
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Self { state: !0 }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    pub fn finish(&self) -> u32 {
        !self.state
    }
}

/// One-shot CRC32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

/// CRC32 over the little-endian byte image of an f32 slice — exactly the
/// bytes `write_store` puts on disk for an `f32` section.
pub fn crc32_f32(vals: &[f32]) -> u32 {
    let mut c = Crc32::new();
    for v in vals {
        c.update(&v.to_le_bytes());
    }
    c.finish()
}

/// CRC32 over the little-endian byte image of a u32 slice.
pub fn crc32_u32(vals: &[u32]) -> u32 {
    let mut c = Crc32::new();
    for v in vals {
        c.update(&v.to_le_bytes());
    }
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_the_ieee_check_vector() {
        // the canonical CRC32 test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn empty_input_is_zero() {
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn streaming_chunks_match_one_shot() {
        let data: Vec<u8> = (0..1024u32).map(|i| (i * 7 + 3) as u8).collect();
        let whole = crc32(&data);
        let mut c = Crc32::new();
        for chunk in data.chunks(13) {
            c.update(chunk);
        }
        assert_eq!(c.finish(), whole);
    }

    #[test]
    fn typed_helpers_match_the_le_byte_image() {
        let f = [1.5f32, -0.25, 3.0e7, f32::MIN_POSITIVE];
        let bytes: Vec<u8> = f.iter().flat_map(|v| v.to_le_bytes()).collect();
        assert_eq!(crc32_f32(&f), crc32(&bytes));

        let u = [0u32, 1, 0xDEAD_BEEF, u32::MAX];
        let bytes: Vec<u8> = u.iter().flat_map(|v| v.to_le_bytes()).collect();
        assert_eq!(crc32_u32(&u), crc32(&bytes));
    }

    #[test]
    fn single_bit_flip_changes_the_checksum() {
        let mut data: Vec<u8> = (0..256u32).map(|i| i as u8).collect();
        let clean = crc32(&data);
        data[100] ^= 0x10;
        assert_ne!(crc32(&data), clean);
    }
}
