//! Minimal JSON value model, parser and writer.
//!
//! Used for `artifacts/manifest.json`, the TCP server protocol and the
//! experiment result files. Supports the full JSON grammar except
//! `\u` surrogate pairs outside the BMP (sufficient for our ASCII data).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as `f64` (the interchange data here is
/// metrics and shapes — all exactly representable).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        if let Json::Obj(map) = self {
            map.insert(key.to_string(), value.into());
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Strict unsigned integer: the value must be a JSON number that is
    /// finite, integral, non-negative and below 2^53 (the largest range an
    /// f64-backed number model can carry without silently losing
    /// precision). Everything else — `-1`, `1.5`, `1e300`, strings,
    /// booleans — returns `None`, so protocol fields can reject malformed
    /// input instead of saturating through an `as` cast.
    pub fn as_strict_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n)
                if n.is_finite()
                    && n.fract() == 0.0
                    && *n >= 0.0
                    && *n < 9_007_199_254_740_992.0 =>
            {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// [`Json::as_strict_u64`] additionally bounded to `u32` — class ids,
    /// shard indices and other small protocol integers.
    pub fn as_strict_u32(&self) -> Option<u32> {
        self.as_strict_u64().filter(|&n| n <= u32::MAX as u64).map(|n| n as u32)
    }

    /// Convenience: `get(key)` then `as_str`, with a descriptive error.
    pub fn str_field(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing string field `{key}`"))
    }

    pub fn num_field(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing numeric field `{key}`"))
    }

    /// Serialise compactly (no whitespace).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<f32> for Json {
    fn from(n: f32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}
impl From<&[f32]> for Json {
    fn from(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}
impl From<&[usize]> for Json {
    fn from(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

/// Parse a JSON document. Returns an error with byte position on failure.
pub fn parse(text: &str) -> anyhow::Result<Json> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        anyhow::bail!("trailing data at byte {pos}");
    }
    Ok(value)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        anyhow::bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, value: Json) -> anyhow::Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        anyhow::bail!("invalid literal at byte {pos}")
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(s.parse::<f64>().map_err(|e| {
        anyhow::anyhow!("bad number `{s}` at byte {start}: {e}")
    })?))
}

fn parse_string(b: &[u8], pos: &mut usize) -> anyhow::Result<String> {
    if b.get(*pos) != Some(&b'"') {
        anyhow::bail!("expected string at byte {pos}");
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        if *pos >= b.len() {
            anyhow::bail!("unterminated string");
        }
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => anyhow::bail!("bad escape at byte {pos}"),
                }
                *pos += 1;
            }
            _ => {
                // consume one UTF-8 scalar
                let rest = std::str::from_utf8(&b[*pos..])
                    .map_err(|_| anyhow::anyhow!("invalid utf8 at byte {pos}"))?;
                let ch = rest.chars().next().unwrap();
                out.push(ch);
                *pos += ch.len_utf8();
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => anyhow::bail!("expected , or ] at byte {pos}"),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    *pos += 1; // '{'
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            anyhow::bail!("expected : at byte {pos}");
        }
        *pos += 1;
        let value = parse_value(b, pos)?;
        map.insert(key, value);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => anyhow::bail!("expected , or }} at byte {pos}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Compact binary payloads
// ---------------------------------------------------------------------------
//
// The shard-worker wire protocol ships query vectors, candidate id lists
// and scored `(f32 distance, u32 row id)` replies inside line-JSON frames.
// Encoding each value as a decimal number would bloat frames ~4× and risk
// a lossy text round-trip for f32s; instead the raw little-endian bytes are
// carried as a base64 string — bit-exact by construction, so the remote
// merge sees the same 32-bit patterns the in-process merge does.

const B64_ALPHABET: &[u8; 64] =
    b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Base64 (standard alphabet, `=` padding) of arbitrary bytes.
pub fn b64_encode(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len().div_ceil(3) * 4);
    for chunk in bytes.chunks(3) {
        let b0 = chunk[0] as u32;
        let b1 = chunk.get(1).copied().unwrap_or(0) as u32;
        let b2 = chunk.get(2).copied().unwrap_or(0) as u32;
        let n = (b0 << 16) | (b1 << 8) | b2;
        out.push(B64_ALPHABET[(n >> 18) as usize & 63] as char);
        out.push(B64_ALPHABET[(n >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 {
            B64_ALPHABET[(n >> 6) as usize & 63] as char
        } else {
            '='
        });
        out.push(if chunk.len() > 2 {
            B64_ALPHABET[n as usize & 63] as char
        } else {
            '='
        });
    }
    out
}

/// Inverse of [`b64_encode`]; rejects bad lengths, stray characters and
/// misplaced padding so a truncated or corrupted frame fails loudly.
pub fn b64_decode(text: &str) -> anyhow::Result<Vec<u8>> {
    let b = text.as_bytes();
    if b.len() % 4 != 0 {
        anyhow::bail!("base64 length {} not a multiple of 4", b.len());
    }
    let mut out = Vec::with_capacity(b.len() / 4 * 3);
    let val = |c: u8, pos: usize| -> anyhow::Result<u32> {
        match c {
            b'A'..=b'Z' => Ok((c - b'A') as u32),
            b'a'..=b'z' => Ok((c - b'a') as u32 + 26),
            b'0'..=b'9' => Ok((c - b'0') as u32 + 52),
            b'+' => Ok(62),
            b'/' => Ok(63),
            _ => anyhow::bail!("bad base64 byte {c:#x} at {pos}"),
        }
    };
    for (i, quad) in b.chunks(4).enumerate() {
        let last = (i + 1) * 4 == b.len();
        let pad = quad.iter().filter(|&&c| c == b'=').count();
        if pad > 0 && (!last || pad > 2 || quad[..4 - pad].contains(&b'=')) {
            anyhow::bail!("misplaced base64 padding in quad {i}");
        }
        let mut n = 0u32;
        for (j, &c) in quad[..4 - pad].iter().enumerate() {
            n |= val(c, i * 4 + j)? << (18 - 6 * j);
        }
        out.push((n >> 16) as u8);
        if pad < 2 {
            out.push((n >> 8) as u8);
        }
        if pad < 1 {
            out.push(n as u8);
        }
    }
    Ok(out)
}

/// f32 slice → base64 of its little-endian bytes (bit-exact round-trip).
pub fn encode_f32s(values: &[f32]) -> String {
    let mut bytes = Vec::with_capacity(values.len() * 4);
    for v in values {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    b64_encode(&bytes)
}

/// Inverse of [`encode_f32s`]; errors when the payload is not a whole
/// number of little-endian f32s.
pub fn decode_f32s(text: &str) -> anyhow::Result<Vec<f32>> {
    let bytes = b64_decode(text)?;
    if bytes.len() % 4 != 0 {
        anyhow::bail!("f32 payload holds {} bytes, not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

/// u32 slice → base64 of its little-endian bytes.
pub fn encode_u32s(values: &[u32]) -> String {
    let mut bytes = Vec::with_capacity(values.len() * 4);
    for v in values {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    b64_encode(&bytes)
}

/// Inverse of [`encode_u32s`].
pub fn decode_u32s(text: &str) -> anyhow::Result<Vec<u32>> {
    let bytes = b64_decode(text)?;
    if bytes.len() % 4 != 0 {
        anyhow::bail!("u32 payload holds {} bytes, not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

/// Scored `(f32 distance, u32 row id)` list → base64 of the interleaved
/// little-endian 32-bit patterns — the shard-worker reply payload.
pub fn encode_scored(list: &[(f32, u32)]) -> String {
    let mut bytes = Vec::with_capacity(list.len() * 8);
    for &(d, id) in list {
        bytes.extend_from_slice(&d.to_le_bytes());
        bytes.extend_from_slice(&id.to_le_bytes());
    }
    b64_encode(&bytes)
}

/// Inverse of [`encode_scored`].
pub fn decode_scored(text: &str) -> anyhow::Result<Vec<(f32, u32)>> {
    let bytes = b64_decode(text)?;
    if bytes.len() % 8 != 0 {
        anyhow::bail!(
            "scored payload holds {} bytes, not a multiple of 8",
            bytes.len()
        );
    }
    Ok(bytes
        .chunks_exact(8)
        .map(|b| {
            (
                f32::from_le_bytes([b[0], b[1], b[2], b[3]]),
                u32::from_le_bytes([b[4], b[5], b[6], b[7]]),
            )
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = parse(text).unwrap();
            assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn roundtrip_object() {
        let mut o = Json::obj();
        o.set("name", "golddiff").set("k", 2048usize).set("ok", true);
        let parsed = parse(&o.to_string_compact()).unwrap();
        assert_eq!(parsed, o);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nope").is_err());
        assert!(parse("{}x").is_err());
    }

    #[test]
    fn numbers_with_exponents() {
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(parse("-2.5E-2").unwrap().as_f64(), Some(-0.025));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap().as_str(), Some("A"));
    }

    #[test]
    fn strict_ints_accept_exact_integers_only() {
        assert_eq!(Json::Num(0.0).as_strict_u64(), Some(0));
        assert_eq!(Json::Num(41.0).as_strict_u64(), Some(41));
        let max = 9_007_199_254_740_991.0; // 2^53 - 1: the last exact f64 int
        assert_eq!(Json::Num(max).as_strict_u64(), Some(max as u64));
        // everything a saturating `as` cast would silently mangle rejects
        assert_eq!(Json::Num(-1.0).as_strict_u64(), None);
        assert_eq!(Json::Num(1.5).as_strict_u64(), None);
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_strict_u64(), None);
        assert_eq!(Json::Num(f64::NAN).as_strict_u64(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_strict_u64(), None);
        assert_eq!(Json::Str("7".into()).as_strict_u64(), None);
        assert_eq!(Json::Bool(true).as_strict_u64(), None);
        assert_eq!(Json::Num(u32::MAX as f64).as_strict_u32(), Some(u32::MAX));
        assert_eq!(Json::Num(u32::MAX as f64 + 1.0).as_strict_u32(), None);
        assert_eq!(Json::Num(-0.0).as_strict_u32(), Some(0));
    }

    #[test]
    fn base64_roundtrips_and_rejects_corruption() {
        // all lengths mod 3, including empty
        for len in 0..20usize {
            let bytes: Vec<u8> = (0..len as u8).map(|i| i.wrapping_mul(37) ^ 0x5a).collect();
            let enc = b64_encode(&bytes);
            assert_eq!(b64_decode(&enc).unwrap(), bytes, "len {len}");
        }
        assert_eq!(b64_encode(b"Man"), "TWFu");
        assert_eq!(b64_encode(b"Ma"), "TWE=");
        assert_eq!(b64_encode(b"M"), "TQ==");
        // truncation, stray bytes and misplaced padding all fail loudly
        assert!(b64_decode("TWF").is_err());
        assert!(b64_decode("TW!u").is_err());
        assert!(b64_decode("TW==TWFu").is_err());
        assert!(b64_decode("T===").is_err());
    }

    #[test]
    fn f32_and_scored_payloads_are_bit_exact() {
        let vals = [
            0.0f32,
            -0.0,
            1.5,
            f32::MIN_POSITIVE,
            f32::MAX,
            f32::NEG_INFINITY,
            f32::NAN,
            -123.456e-7,
        ];
        let back = decode_f32s(&encode_f32s(&vals)).unwrap();
        assert_eq!(back.len(), vals.len());
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "payload must be bit-exact");
        }
        let ids = [0u32, 1, u32::MAX, 41];
        assert_eq!(decode_u32s(&encode_u32s(&ids)).unwrap(), ids);
        let scored = [(0.25f32, 7u32), (f32::INFINITY, 0), (-0.0, u32::MAX)];
        let back = decode_scored(&encode_scored(&scored)).unwrap();
        for ((da, ia), (db, ib)) in scored.iter().zip(&back) {
            assert_eq!(da.to_bits(), db.to_bits());
            assert_eq!(ia, ib);
        }
        // a frame cut mid-value fails instead of decoding short
        let enc = encode_scored(&scored);
        assert!(decode_scored(&enc[..enc.len() - 8]).is_err());
    }
}
