//! Minimal JSON value model, parser and writer.
//!
//! Used for `artifacts/manifest.json`, the TCP server protocol and the
//! experiment result files. Supports the full JSON grammar except
//! `\u` surrogate pairs outside the BMP (sufficient for our ASCII data).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as `f64` (the interchange data here is
/// metrics and shapes — all exactly representable).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        if let Json::Obj(map) = self {
            map.insert(key.to_string(), value.into());
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience: `get(key)` then `as_str`, with a descriptive error.
    pub fn str_field(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing string field `{key}`"))
    }

    pub fn num_field(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing numeric field `{key}`"))
    }

    /// Serialise compactly (no whitespace).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<f32> for Json {
    fn from(n: f32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}
impl From<&[f32]> for Json {
    fn from(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}
impl From<&[usize]> for Json {
    fn from(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

/// Parse a JSON document. Returns an error with byte position on failure.
pub fn parse(text: &str) -> anyhow::Result<Json> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        anyhow::bail!("trailing data at byte {pos}");
    }
    Ok(value)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        anyhow::bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, value: Json) -> anyhow::Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        anyhow::bail!("invalid literal at byte {pos}")
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(s.parse::<f64>().map_err(|e| {
        anyhow::anyhow!("bad number `{s}` at byte {start}: {e}")
    })?))
}

fn parse_string(b: &[u8], pos: &mut usize) -> anyhow::Result<String> {
    if b.get(*pos) != Some(&b'"') {
        anyhow::bail!("expected string at byte {pos}");
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        if *pos >= b.len() {
            anyhow::bail!("unterminated string");
        }
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => anyhow::bail!("bad escape at byte {pos}"),
                }
                *pos += 1;
            }
            _ => {
                // consume one UTF-8 scalar
                let rest = std::str::from_utf8(&b[*pos..])
                    .map_err(|_| anyhow::anyhow!("invalid utf8 at byte {pos}"))?;
                let ch = rest.chars().next().unwrap();
                out.push(ch);
                *pos += ch.len_utf8();
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => anyhow::bail!("expected , or ] at byte {pos}"),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    *pos += 1; // '{'
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            anyhow::bail!("expected : at byte {pos}");
        }
        *pos += 1;
        let value = parse_value(b, pos)?;
        map.insert(key, value);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => anyhow::bail!("expected , or }} at byte {pos}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = parse(text).unwrap();
            assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn roundtrip_object() {
        let mut o = Json::obj();
        o.set("name", "golddiff").set("k", 2048usize).set("ok", true);
        let parsed = parse(&o.to_string_compact()).unwrap();
        assert_eq!(parsed, o);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nope").is_err());
        assert!(parse("{}x").is_err());
    }

    #[test]
    fn numbers_with_exponents() {
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(parse("-2.5E-2").unwrap().as_f64(), Some(-0.025));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap().as_str(), Some("A"));
    }
}
