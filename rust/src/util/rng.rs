//! Deterministic PRNG (PCG-XSH-RR 64/32) + distribution helpers.
//!
//! Every stochastic component of the system (dataset synthesis, workload
//! generation, samplers, property tests) threads one of these through, so
//! all experiments are reproducible from a seed.

/// PCG-XSH-RR 64/32 — small, fast, statistically solid for simulation use.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const MUL: u64 = 6364136223846793005;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Independent stream for the same seed (used to decorrelate workers).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MUL).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) via Lemire rejection.
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        (self.f64() * bound as f64) as usize % bound
    }

    /// Standard normal via Box–Muller (cached spare skipped for simplicity).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-7);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fill a slice with iid N(0, 1).
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut u = self.f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_are_decorrelated() {
        let mut a = Pcg64::with_stream(7, 1);
        let mut b = Pcg64::with_stream(7, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut rng = Pcg64::new(1);
        for _ in 0..10_000 {
            let x = rng.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(3);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn choose_k_distinct() {
        let mut rng = Pcg64::new(5);
        let picked = rng.choose_k(100, 20);
        let mut sorted = picked.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Pcg64::new(9);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac = counts[2] as f64 / 30_000.0;
        assert!((frac - 0.7).abs() < 0.03);
    }

    #[test]
    fn below_bounds() {
        let mut rng = Pcg64::new(11);
        for bound in [1usize, 2, 3, 17, 1000] {
            for _ in 0..200 {
                assert!(rng.below(bound) < bound);
            }
        }
    }
}
