//! Wall-clock timing helpers used by the bench harnesses and engine stats.

use std::time::{Duration, Instant};

/// Accumulates durations and reports summary statistics.
#[derive(Debug, Default, Clone)]
pub struct TimingStats {
    samples: Vec<f64>, // seconds
}

impl TimingStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples.push(d.as_secs_f64());
    }

    pub fn record_secs(&mut self, s: f64) {
        self.samples.push(s);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn total(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.total() / self.samples.len() as f64
        }
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
        sorted[idx]
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn merge(&mut self, other: &TimingStats) {
        self.samples.extend_from_slice(&other.samples);
    }
}

/// Times a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed().as_secs_f64())
}

/// Scope timer: records into a `TimingStats` on drop.
pub struct ScopedTimer<'a> {
    stats: &'a mut TimingStats,
    start: Instant,
}

impl<'a> ScopedTimer<'a> {
    pub fn new(stats: &'a mut TimingStats) -> Self {
        Self {
            stats,
            start: Instant::now(),
        }
    }
}

impl Drop for ScopedTimer<'_> {
    fn drop(&mut self) {
        self.stats.record(self.start.elapsed());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let mut t = TimingStats::new();
        for s in [1.0, 2.0, 3.0, 4.0] {
            t.record_secs(s);
        }
        assert_eq!(t.count(), 4);
        assert!((t.mean() - 2.5).abs() < 1e-12);
        assert!((t.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((t.percentile(1.0) - 4.0).abs() < 1e-12);
        assert_eq!(t.min(), 1.0);
    }

    #[test]
    fn time_it_returns_result() {
        let (v, secs) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn scoped_timer_records() {
        let mut t = TimingStats::new();
        {
            let _g = ScopedTimer::new(&mut t);
        }
        assert_eq!(t.count(), 1);
    }
}
