//! Minimal scoped data-parallel helpers over std threads.
//!
//! The serving engine and the coarse-scan index want "run this closure over
//! chunk ranges on N threads and join" — `parallel_chunks` provides exactly
//! that with zero allocation on the steady path. A long-lived `WorkerPool`
//! (channel-fed) backs the coordinator's continuous-batching loop.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Number of worker threads to use by default: physical parallelism capped
/// to keep the PJRT CPU client responsive.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Split `[0, len)` into `chunks` half-open ranges of near-equal size.
///
/// Edge cases: `chunks == 0` yields no ranges (nothing can run the work);
/// `len == 0` with `chunks > 0` yields one empty range `(0, 0)` so
/// `parallel_chunks` still invokes the closure exactly once through its
/// single-range fast path — callers get a result of consistent shape (one
/// shard of empty output) whether the input is empty or merely small,
/// instead of a zero-shard special case.
pub fn split_ranges(len: usize, chunks: usize) -> Vec<(usize, usize)> {
    if chunks == 0 {
        return vec![];
    }
    if len == 0 {
        return vec![(0, 0)];
    }
    let chunks = chunks.min(len);
    let base = len / chunks;
    let extra = len % chunks;
    let mut out = Vec::with_capacity(chunks);
    let mut start = 0;
    for i in 0..chunks {
        let size = base + usize::from(i < extra);
        out.push((start, start + size));
        start += size;
    }
    out
}

/// Run `f(chunk_index, start, end)` over the ranges of `[0, len)` on up to
/// `threads` scoped threads, collecting each chunk's return value in order.
pub fn parallel_chunks<T, F>(len: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, usize, usize) -> T + Sync,
{
    let ranges = split_ranges(len, threads.max(1));
    if ranges.len() <= 1 {
        return ranges
            .into_iter()
            .enumerate()
            .map(|(i, (s, e))| f(i, s, e))
            .collect();
    }
    let mut out: Vec<Option<T>> = (0..ranges.len()).map(|_| None).collect();
    thread::scope(|scope| {
        let mut handles = Vec::with_capacity(ranges.len());
        for (i, (s, e)) in ranges.iter().copied().enumerate() {
            let fref = &f;
            handles.push(scope.spawn(move || (i, fref(i, s, e))));
        }
        for h in handles {
            let (i, v) = h.join().expect("worker panicked");
            out[i] = Some(v);
        }
    });
    out.into_iter().map(|v| v.unwrap()).collect()
}

/// Work-stealing-free dynamic scheduler: threads atomically grab fixed-size
/// tiles until the range is exhausted. Better than static chunks when tile
/// costs vary (e.g. conditional class shards of very different sizes).
pub fn parallel_tiles<F>(len: usize, tile: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let cursor = AtomicUsize::new(0);
    thread::scope(|scope| {
        for _ in 0..threads.max(1) {
            let cursor = &cursor;
            let fref = &f;
            scope.spawn(move || loop {
                let start = cursor.fetch_add(tile, Ordering::Relaxed);
                if start >= len {
                    break;
                }
                fref(start, (start + tile).min(len));
            });
        }
    });
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Long-lived FIFO worker pool for the coordinator's dispatch loop.
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    inflight: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl WorkerPool {
    pub fn new(threads: usize) -> Self {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let mut handles = Vec::new();
        for _ in 0..threads.max(1) {
            let rx = Arc::clone(&rx);
            let inflight = Arc::clone(&inflight);
            handles.push(thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => {
                        job();
                        let (lock, cvar) = &*inflight;
                        let mut n = lock.lock().unwrap();
                        *n -= 1;
                        cvar.notify_all();
                    }
                    Err(_) => break,
                }
            }));
        }
        WorkerPool {
            tx: Some(tx),
            handles,
            inflight,
        }
    }

    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        let (lock, _) = &*self.inflight;
        *lock.lock().unwrap() += 1;
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker pool hung up");
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cvar) = &*self.inflight;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cvar.wait(n).unwrap();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn ranges_partition_exactly() {
        for (len, chunks) in [(10, 3), (7, 7), (100, 8), (3, 16), (0, 4)] {
            let r = split_ranges(len, chunks);
            let total: usize = r.iter().map(|(s, e)| e - s).sum();
            assert_eq!(total, len);
            for w in r.windows(2) {
                assert_eq!(w[0].1, w[1].0); // contiguous
            }
        }
    }

    #[test]
    fn ranges_edge_cases_len_vs_chunks() {
        // Satellite: len < chunks never yields empty ranges — chunks clamp
        assert_eq!(split_ranges(3, 16), vec![(0, 1), (1, 2), (2, 3)]);
        assert_eq!(split_ranges(1, 2), vec![(0, 1)]);
        // len == 0 yields exactly one empty range (the single-range fast
        // path of parallel_chunks runs it inline, no threads spawned)
        assert_eq!(split_ranges(0, 1), vec![(0, 0)]);
        assert_eq!(split_ranges(0, 8), vec![(0, 0)]);
        // chunks == 0 yields nothing — there is no worker to run it
        assert_eq!(split_ranges(0, 0), Vec::<(usize, usize)>::new());
        assert_eq!(split_ranges(5, 0), Vec::<(usize, usize)>::new());
    }

    #[test]
    fn parallel_chunks_empty_input_invokes_closure_once() {
        // consistent shape: one shard of empty output, not zero shards
        let calls = AtomicU64::new(0);
        let out = parallel_chunks(0, 4, |i, s, e| {
            calls.fetch_add(1, Ordering::Relaxed);
            assert_eq!((i, s, e), (0, 0, 0));
            Vec::<u32>::new()
        });
        assert_eq!(out.len(), 1);
        assert!(out[0].is_empty());
        assert_eq!(calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn parallel_chunks_sums_correctly() {
        let data: Vec<u64> = (0..10_000).collect();
        let partials = parallel_chunks(data.len(), 8, |_, s, e| {
            data[s..e].iter().sum::<u64>()
        });
        assert_eq!(partials.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn parallel_tiles_visits_everything_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_tiles(1000, 64, 4, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn worker_pool_runs_jobs_and_waits() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }
}
