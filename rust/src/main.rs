//! `golddiff` — the launcher CLI for the GoldDiff serving stack.
//!
//! Commands:
//!   gen-data   synthesise + cache the benchmark dataset stores (.gds)
//!   serve      start the TCP serving engine for one preset
//!   shard-worker  serve shard retrieval ops for a distributed coordinator
//!   generate   run generations locally through the engine and print stats
//!   exp        regenerate a paper table/figure (table1..table7, fig1, fig3, fig6, all)
//!   info       summarise artifacts + datasets
//!
//! Example:
//!   golddiff gen-data --all
//!   golddiff serve --preset cifar-sim --addr 127.0.0.1:7391
//!   golddiff generate --preset afhq-sim --method golddiff-pca --count 8
//!   golddiff exp table2

use std::sync::Arc;

use anyhow::Result;

use golddiff::benchlib::{self, experiments, figures};
use golddiff::config::EngineConfig;
use golddiff::coordinator::Engine;
use golddiff::data::store;
use golddiff::data::synthetic::{preset, PRESETS};
use golddiff::denoiser::DenoiserKind;
use golddiff::index::{RetrievalBackendKind, ShardedBackend};
use golddiff::server::worker::ShardWorker;
use golddiff::server::Server;
use golddiff::util::cli::{Args, Cli};

fn main() {
    let cli = Cli::new("golddiff", "Fast and Scalable Analytical Diffusion (GoldDiff)")
        .command("gen-data", "synthesise + cache benchmark datasets")
        .command("serve", "start the TCP serving engine")
        .command("shard-worker", "serve shard retrieval ops for a distributed coordinator")
        .command("generate", "run local generations and print stats")
        .command("exp", "regenerate a paper table/figure")
        .command("info", "summarise artifacts and datasets");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, args)) = cli.dispatch(&argv) else {
        eprint!("{}", cli.usage());
        std::process::exit(2);
    };
    let code = match run(&cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "gen-data" => gen_data(args),
        "serve" => serve(args),
        "shard-worker" => shard_worker(args),
        "generate" => generate(args),
        "exp" => exp(args),
        "info" => info(args),
        _ => unreachable!(),
    }
}

fn gen_data(args: &Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.get_or("out-dir", "data"));
    let seed = args.u64_or("seed", 0);
    // shard-aware ingest: --cluster-order N permutes rows by proxy-space
    // k-means cluster (N lists) before the shard split, so contiguous
    // shards are spatially coherent and the warm screen's whole-shard
    // skips fire; --shards saves the v3 per-shard sections for streaming
    let order_lists = args.usize_or("cluster-order", 0);
    let shards = args.usize_or("shards", 1);
    let names: Vec<&str> = if args.flag("all") {
        PRESETS.iter().map(|p| p.name).collect()
    } else {
        vec![args.get_or("preset", "cifar-sim")]
    };
    for name in names {
        let spec = preset(name).ok_or_else(|| anyhow::anyhow!("unknown preset {name}"))?;
        let path = store::store_path(&dir, name);
        if path.exists() && !args.flag("force") {
            println!("{name}: cached at {path:?}");
            continue;
        }
        let t0 = std::time::Instant::now();
        let mut ds = golddiff::Dataset::synthesize(spec, seed);
        if order_lists > 0 {
            ds = ds.with_clustered_rows(order_lists, seed);
        }
        store::save_sharded(&ds, &path, shards)?;
        println!(
            "{name}: N={} D={} classes={}{} -> {path:?} ({:.1}s)",
            ds.n,
            ds.d,
            ds.classes,
            if order_lists > 0 {
                format!(" cluster-ordered({order_lists})")
            } else {
                String::new()
            },
            t0.elapsed().as_secs_f64()
        );
    }
    Ok(())
}

fn engine_from_args(args: &Args) -> Result<Engine> {
    let mut cfg = EngineConfig::default();
    if let Some(path) = args.get("config") {
        cfg = EngineConfig::load(std::path::Path::new(path))?;
    }
    cfg.apply_args(args);
    Engine::start(cfg)
}

fn serve(args: &Args) -> Result<()> {
    let engine = Arc::new(engine_from_args(args)?);
    let addr = args.get_or("addr", "127.0.0.1:7391");
    let server = Server::start(Arc::clone(&engine), addr)?;
    println!(
        "golddiff serving preset={} on {} ({} steps) — line-JSON protocol; Ctrl-C to stop",
        engine.preset, server.addr, engine.steps
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(5));
        println!("stats: {}", engine.stats_json());
    }
}

/// Run one shard-worker process: open the preset's store data-free with
/// the assigned shards pre-touched, build the full sharded backend over it
/// (ops name their shard subset explicitly, so the worker itself stays
/// stateless), and answer retrieval ops over the line-JSON wire protocol
/// until killed. `--assigned 1,3` restricts the warm-up to the shards a
/// coordinator will actually route here; default warms every shard.
fn shard_worker(args: &Args) -> Result<()> {
    let mut cfg = EngineConfig::default();
    if let Some(path) = args.get("config") {
        cfg = EngineConfig::load(std::path::Path::new(path))?;
    }
    cfg.apply_args(args);
    let shards = cfg.shards.max(1);
    let assigned: Vec<usize> = match args.get("assigned") {
        Some(list) => list
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| {
                s.trim()
                    .parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("bad --assigned entry `{s}`"))
            })
            .collect::<Result<_>>()?,
        None => (0..shards).collect(),
    };
    let path = store::ensure_store(&cfg.data_dir, &cfg.preset, cfg.seed, shards)?;
    let ds = Arc::new(store::open_worker(&path, shards, cfg.mem_budget_mb, &assigned)?);
    let kind = RetrievalBackendKind::parse(&cfg.backend)
        .ok_or_else(|| anyhow::anyhow!("unknown backend {}", cfg.backend))?;
    let backend = Arc::new(ShardedBackend::build(&ds, kind, cfg.backend_opts()));
    let addr = args.get_or("addr", "127.0.0.1:7461");
    let worker = ShardWorker::start(Arc::clone(&ds), backend, addr)?;
    println!(
        "golddiff shard-worker preset={} shards={shards} assigned={assigned:?} on {}",
        cfg.preset, worker.addr
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(60));
    }
}

fn generate(args: &Args) -> Result<()> {
    let engine = engine_from_args(args)?;
    let method = DenoiserKind::parse(args.get_or("method", "golddiff-pca"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;
    let count = args.usize_or("count", 4);
    let class = args.get("class").and_then(|c| c.parse().ok());
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..count)
        .map(|i| engine.submit(method, args.u64_or("seed", 0) + i as u64, class))
        .collect::<Result<_>>()?;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv()?;
        println!(
            "sample {i}: latency={:.3}s queue={:.3}s steps={} k: {} -> {}",
            resp.latency_secs,
            resp.queue_secs,
            resp.steps.len(),
            resp.steps.first().map(|s| s.k_used).unwrap_or(0),
            resp.steps.last().map(|s| s.k_used).unwrap_or(0),
        );
    }
    println!(
        "total {:.3}s, throughput {:.2} samples/s",
        t0.elapsed().as_secs_f64(),
        count as f64 / t0.elapsed().as_secs_f64()
    );
    println!("engine stats: {}", engine.stats_json());
    engine.shutdown();
    Ok(())
}

fn exp(args: &Args) -> Result<()> {
    let which = args
        .positional()
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let seed = args.u64_or("seed", 0);
    let run_one = |name: &str| -> Result<()> {
        eprintln!("== {name} ==");
        match name {
            "table1" => {
                experiments::run_table1(&[2500, 5000, 10_000, 20_000], seed)?;
            }
            "table2" => {
                experiments::run_table2(seed)?;
            }
            "table3" => {
                experiments::run_table3(seed)?;
            }
            "table4" => {
                experiments::run_table4(seed)?;
            }
            "table5" => {
                experiments::run_table5(seed)?;
            }
            "table6" => {
                experiments::run_table6(seed)?;
            }
            "table7" => {
                experiments::run_table7(seed)?;
            }
            "fig1" => {
                figures::run_concentration("moons", 8, seed)?;
            }
            "fig3" => {
                figures::run_concentration("cifar-sim", 4, seed)?;
                figures::run_sensitivity("cifar-sim", seed)?;
            }
            "fig4" => {
                figures::run_qualitative("cifar-sim", 8, seed)?;
            }
            "fig6" => {
                experiments::run_fig6(seed)?;
            }
            other => anyhow::bail!("unknown experiment `{other}`"),
        }
        Ok(())
    };
    if which == "all" {
        for name in [
            "fig1", "table1", "table2", "table4", "table5", "table6", "table7", "fig3", "fig4",
            "fig6", "table3",
        ] {
            run_one(name)?;
        }
    } else {
        run_one(which)?;
    }
    Ok(())
}

fn info(_args: &Args) -> Result<()> {
    let rt = benchlib::runtime()?;
    println!("artifacts: {} graphs", rt.manifest.artifacts.len());
    for p in &rt.manifest.presets {
        let buckets = rt.manifest.buckets("golden_step", &p.name);
        println!(
            "  {:14} N={:6} D={:5} proxy_d={:4} classes={:4} buckets={:?}",
            p.name, p.n, p.d, p.proxy_d, p.classes, buckets
        );
    }
    let dir = benchlib::data_dir();
    for p in PRESETS {
        let path = store::store_path(&dir, p.name);
        println!(
            "  data/{:18} {}",
            format!("{}.gds", p.name),
            if path.exists() {
                "cached"
            } else {
                "missing (golddiff gen-data)"
            }
        );
    }
    Ok(())
}
