//! Shard-worker server: the distributed tier's data-plane node.
//!
//! A worker owns a [`ShardedBackend`] over the full shard plan and answers
//! retrieval ops for the **explicit shard subset named in each request** —
//! the worker itself is stateless about shard assignment, so a coordinator
//! can re-route shards after a worker loss without any rebalancing
//! handshake. Payload vectors travel as base64 of little-endian 32-bit
//! patterns ([`crate::util::json`]), so every f32 distance crosses the
//! wire bit-exactly and the coordinator's `(distance, row id)` merge
//! reproduces the in-process result byte for byte.
//!
//! Protocol (one JSON document per line, mirroring the front-end server):
//!   → {"op":"ping"}                 ← {"ok":true,"pong":true,"shards":…}
//!   → {"op":"health"}               ← {"ok":true,"status":"ok",…}
//!   → {"op":"coarse_screen","queries":b64f32,"classes":b64u32,"m":…,
//!      "shards":b64u32[,"deadline_ms":…]}
//!                                   ← {"ok":true,"results":[b64scored,…]}
//!   → {"op":"warm_screen","query":b64f32[,"class":…],"m":…,"seeds":b64u32,
//!      "shards":b64u32[,"deadline_ms":…]}
//!                                   ← {"ok":true,"found":bool[,"result":b64scored]}
//!   → {"op":"masked_refine","queries":b64f32,"pools":[b64u32,…],"k":…
//!      [,"deadline_ms":…]}          ← {"ok":true,"results":[b64scored,…]}
//!
//! `classes` carries one u32 per query with `u32::MAX` meaning
//! unconditional. A malformed field answers the machine-readable
//! `{"ok":false,"error":"bad_field:<name>"}` and the connection keeps
//! serving — same validation discipline as the front end. An op whose
//! `deadline_ms` has already elapsed at receipt (`0` is the deterministic
//! always-expired hook) answers `{"ok":false,"error":"deadline_exceeded"}`
//! *before* any scan work — the requester has already given up, so the
//! worker refuses to burn the pass.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::request::{strict_u32_field, strict_u64_field};
use crate::data::dataset::Dataset;
use crate::index::backend::{ProxyQuery, RetrievalBackend};
use crate::index::shard::ShardedBackend;
use crate::util::json::{decode_f32s, decode_u32s, encode_scored, parse, Json};

/// A running shard worker (owns the accept thread).
pub struct ShardWorker {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl ShardWorker {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve retrieval ops against
    /// `backend` until [`stop`](ShardWorker::stop). The accept loop
    /// mirrors the front-end server: non-blocking accept with finished
    /// connections reaped each pass, and transient accept failures logged
    /// once per distinct [`std::io::ErrorKind`] instead of killing the
    /// listener.
    pub fn start(
        ds: Arc<Dataset>,
        backend: Arc<ShardedBackend>,
        addr: &str,
    ) -> Result<ShardWorker> {
        let listener =
            std::net::TcpListener::bind(addr).with_context(|| format!("binding worker {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let sd = Arc::clone(&shutdown);
        let handle = std::thread::Builder::new()
            .name("golddiff-worker".into())
            .spawn(move || {
                let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
                let mut accept_errs_logged = std::collections::HashSet::new();
                while !sd.load(Ordering::Relaxed) {
                    conns = conns
                        .into_iter()
                        .filter_map(|c| {
                            if c.is_finished() {
                                let _ = c.join();
                                None
                            } else {
                                Some(c)
                            }
                        })
                        .collect();
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let ds2 = Arc::clone(&ds);
                            let be2 = Arc::clone(&backend);
                            let sd2 = Arc::clone(&sd);
                            conns.push(std::thread::spawn(move || {
                                let _ = handle_conn(stream, ds2, be2, sd2);
                            }));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(10));
                        }
                        Err(e) => {
                            if accept_errs_logged.insert(e.kind()) {
                                eprintln!("golddiff: worker: accept failed ({e}); retrying");
                            }
                            std::thread::sleep(std::time::Duration::from_millis(50));
                        }
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
            })?;
        Ok(ShardWorker {
            addr: local,
            shutdown,
            handle: Some(handle),
        })
    }

    /// Signal shutdown and join the accept thread. Idempotent — the
    /// coordinator's `Drop` and an explicit stop can both run.
    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ShardWorker {
    fn drop(&mut self) {
        self.stop();
    }
}

fn handle_conn(
    stream: TcpStream,
    ds: Arc<Dataset>,
    backend: Arc<ShardedBackend>,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    // periodic read timeout so connection threads observe shutdown instead
    // of blocking forever in read_line
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // coordinator closed
            Ok(_) => {
                let t0 = Instant::now();
                let reply = match handle_line(line.trim(), &ds, &backend, t0) {
                    Ok(j) => j,
                    Err(e) => {
                        // a malformed or expired op is a clean protocol
                        // reply, not a connection error — the stream keeps
                        // serving the coordinator's next op
                        let mut j = Json::obj();
                        j.set("ok", false).set("error", e.to_string());
                        j
                    }
                };
                line.clear();
                stream.write_all(reply.to_string_compact().as_bytes())?;
                stream.write_all(b"\n")?;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if shutdown.load(Ordering::Relaxed) {
                    return Ok(());
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Refuse an op whose requester has already expired: `deadline_ms` is the
/// remaining budget at send time, `t0` the op's receipt instant. `0` is
/// the deterministic always-expired hook the tests lean on — in
/// production the coordinator never sends an op it knows is dead, so a
/// zero only arrives when the deadline collapsed in flight.
fn deadline_gate(req: &Json, t0: Instant) -> Result<()> {
    if let Some(dl) = strict_u64_field(req, "deadline_ms")? {
        if dl == 0 || t0.elapsed().as_millis() as u64 >= dl {
            anyhow::bail!("deadline_exceeded");
        }
    }
    Ok(())
}

/// Decode a base64 payload field, mapping any decode failure to the
/// field's `bad_field:<name>` protocol error.
fn payload<T>(req: &Json, name: &str, decode: impl Fn(&str) -> Result<Vec<T>>) -> Result<Vec<T>> {
    let text = req
        .get(name)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("bad_field:{name}"))?;
    decode(text).map_err(|_| anyhow!("bad_field:{name}"))
}

/// Required strict unsigned field (`m`, `k`): absent or malformed answers
/// the same `bad_field` error — a worker op without a budget is malformed.
fn required_usize(req: &Json, name: &str) -> Result<usize> {
    Ok(strict_u64_field(req, name)
        .map_err(|_| anyhow!("bad_field:{name}"))?
        .ok_or_else(|| anyhow!("bad_field:{name}"))? as usize)
}

/// Decode + validate the `shards` subset payload: every id must name a
/// shard of the plan — the coordinator and worker must agree on the plan,
/// and a stale id is a routing bug worth surfacing, not ignoring.
fn shard_subset(req: &Json, ns: usize) -> Result<Vec<usize>> {
    let raw = payload(req, "shards", decode_u32s)?;
    if raw.iter().any(|&s| s as usize >= ns) {
        anyhow::bail!("bad_field:shards");
    }
    Ok(raw.into_iter().map(|s| s as usize).collect())
}

fn handle_line(line: &str, ds: &Dataset, backend: &ShardedBackend, t0: Instant) -> Result<Json> {
    let req = parse(line)?;
    let op = req.str_field("op")?;
    let ns = backend.corpus().plan().count();
    match op {
        "ping" => {
            let mut j = Json::obj();
            j.set("ok", true)
                .set("pong", true)
                .set("shards", ns)
                .set("rows", ds.n)
                .set("proxy_d", ds.proxy_d);
            Ok(j)
        }
        "health" => {
            let mut j = Json::obj();
            j.set("ok", true)
                .set("status", "ok")
                .set("backend", backend.name())
                .set("shards", ns);
            Ok(j)
        }
        "coarse_screen" => {
            let queries = payload(&req, "queries", decode_f32s)?;
            if queries.is_empty() || queries.len() % ds.proxy_d.max(1) != 0 {
                anyhow::bail!("bad_field:queries");
            }
            let nq = queries.len() / ds.proxy_d.max(1);
            let classes = payload(&req, "classes", decode_u32s)?;
            if classes.len() != nq {
                anyhow::bail!("bad_field:classes");
            }
            let m = required_usize(&req, "m")?;
            let subset = shard_subset(&req, ns)?;
            deadline_gate(&req, t0)?;
            let pq: Vec<ProxyQuery> = (0..nq)
                .map(|i| ProxyQuery {
                    proxy: &queries[i * ds.proxy_d..(i + 1) * ds.proxy_d],
                    class: (classes[i] != u32::MAX).then_some(classes[i]),
                })
                .collect();
            let res = backend.screen_scored(ds, &pq, m, &subset);
            let mut j = Json::obj();
            j.set("ok", true).set(
                "results",
                Json::Arr(res.iter().map(|l| Json::Str(encode_scored(l))).collect()),
            );
            Ok(j)
        }
        "warm_screen" => {
            let query = payload(&req, "query", decode_f32s)?;
            if query.len() != ds.proxy_d {
                anyhow::bail!("bad_field:query");
            }
            let class = strict_u32_field(&req, "class")?;
            let m = required_usize(&req, "m")?;
            let seeds = payload(&req, "seeds", decode_u32s)?;
            // the bounded sweep binary-searches the seed list, so the
            // protocol requires it sorted strictly ascending (and in
            // range) — a violation is a coordinator bug, not a fallback
            if seeds.windows(2).any(|w| w[0] >= w[1])
                || seeds.last().is_some_and(|&s| s as usize >= ds.n)
            {
                anyhow::bail!("bad_field:seeds");
            }
            let subset = shard_subset(&req, ns)?;
            deadline_gate(&req, t0)?;
            let mut j = Json::obj();
            match backend.warm_scored(ds, &query, class, m, &seeds, &subset) {
                Some(sc) => {
                    j.set("ok", true)
                        .set("found", true)
                        .set("result", Json::Str(encode_scored(&sc)));
                }
                None => {
                    // too few eligible seeds for the cap — a *global*
                    // property every worker agrees on, so the coordinator
                    // sees a unanimous miss and falls back cold
                    j.set("ok", true).set("found", false);
                }
            }
            Ok(j)
        }
        "masked_refine" => {
            let queries = payload(&req, "queries", decode_f32s)?;
            if queries.is_empty() || queries.len() % ds.d.max(1) != 0 {
                anyhow::bail!("bad_field:queries");
            }
            let nq = queries.len() / ds.d.max(1);
            let pools_json = req
                .get("pools")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("bad_field:pools"))?;
            if pools_json.len() != nq {
                anyhow::bail!("bad_field:pools");
            }
            let pools: Vec<Vec<u32>> = pools_json
                .iter()
                .map(|p| {
                    p.as_str()
                        .ok_or_else(|| anyhow!("bad_field:pools"))
                        .and_then(|s| decode_u32s(s).map_err(|_| anyhow!("bad_field:pools")))
                })
                .collect::<Result<_>>()?;
            if pools.iter().flatten().any(|&id| id as usize >= ds.n) {
                anyhow::bail!("bad_field:pools");
            }
            let k = required_usize(&req, "k")?;
            deadline_gate(&req, t0)?;
            let qs: Vec<&[f32]> = (0..nq).map(|i| &queries[i * ds.d..(i + 1) * ds.d]).collect();
            let ps: Vec<&[u32]> = pools.iter().map(Vec::as_slice).collect();
            let res = backend.refine_scored(ds, &qs, &ps, k);
            let mut j = Json::obj();
            j.set("ok", true).set(
                "results",
                Json::Arr(res.iter().map(|l| Json::Str(encode_scored(l))).collect()),
            );
            Ok(j)
        }
        other => anyhow::bail!("unknown op `{other}`"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;
    use crate::index::backend::{BackendOpts, RetrievalBackend, RetrievalBackendKind};
    use crate::util::json::{decode_scored, encode_f32s, encode_u32s};

    fn tiny(n: usize, seed: u64) -> Dataset {
        let mut spec = preset("cifar-sim").unwrap().clone();
        spec.n = n;
        Dataset::synthesize(&spec, seed)
    }

    fn worker(ds: &Arc<Dataset>, shards: usize) -> (ShardWorker, Arc<ShardedBackend>) {
        let opts = BackendOpts {
            threads: 2,
            shards,
            kernel: true,
            refine_kernel: true,
            ..BackendOpts::default()
        };
        let be = Arc::new(ShardedBackend::build(ds, RetrievalBackendKind::Batched, opts));
        let w = ShardWorker::start(Arc::clone(ds), Arc::clone(&be), "127.0.0.1:0").unwrap();
        (w, be)
    }

    fn call(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, raw: &str) -> Json {
        stream.write_all(raw.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        parse(line.trim()).unwrap()
    }

    fn connect(addr: &std::net::SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(10)))
            .unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        (stream, reader)
    }

    #[test]
    fn coarse_screen_over_tcp_matches_in_process_subset_scan() {
        let ds = Arc::new(tiny(180, 5));
        let (mut w, be) = worker(&ds, 3);
        let (mut stream, mut reader) = connect(&w.addr);

        let pong = call(&mut stream, &mut reader, r#"{"op":"ping"}"#);
        assert_eq!(pong.get("pong").and_then(Json::as_bool), Some(true));
        assert_eq!(pong.get("shards").and_then(Json::as_f64), Some(3.0));

        let mut rng = crate::util::rng::Pcg64::new(11);
        let qdata: Vec<f32> = (0..2 * ds.proxy_d).map(|_| rng.normal()).collect();
        let mut req = Json::obj();
        req.set("op", "coarse_screen")
            .set("queries", encode_f32s(&qdata).as_str())
            .set("classes", encode_u32s(&[u32::MAX, 2]).as_str())
            .set("m", 17_u64)
            .set("shards", encode_u32s(&[0, 2]).as_str());
        let resp = call(&mut stream, &mut reader, &req.to_string_compact());
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        let results = resp.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        let got: Vec<Vec<(f32, u32)>> = results
            .iter()
            .map(|r| decode_scored(r.as_str().unwrap()).unwrap())
            .collect();

        let pq = [
            ProxyQuery {
                proxy: &qdata[..ds.proxy_d],
                class: None,
            },
            ProxyQuery {
                proxy: &qdata[ds.proxy_d..],
                class: Some(2),
            },
        ];
        let want = be.screen_scored(&ds, &pq, 17, &[0, 2]);
        assert_eq!(got, want, "wire round-trip must be bit-exact");
        w.stop();
    }

    #[test]
    fn malformed_and_truncated_frames_answer_bad_field_and_stream_survives() {
        let ds = Arc::new(tiny(90, 7));
        let (mut w, _be) = worker(&ds, 2);
        let (mut stream, mut reader) = connect(&w.addr);

        // truncated base64 (not a multiple of 4), wrong-length payloads,
        // out-of-range ids, malformed numerics — each answers its field's
        // bad_field error and the connection keeps serving
        let m_ok = r#""m":5"#;
        let cases: Vec<(String, &str)> = vec![
            (
                format!(
                    r#"{{"op":"coarse_screen","queries":"AAA","classes":"{}",{m_ok},"shards":"{}"}}"#,
                    encode_u32s(&[u32::MAX]),
                    encode_u32s(&[0])
                ),
                "bad_field:queries",
            ),
            (
                format!(
                    r#"{{"op":"coarse_screen","queries":"{}","classes":"{}",{m_ok},"shards":"{}"}}"#,
                    encode_f32s(&vec![0.5; ds.proxy_d]),
                    encode_u32s(&[u32::MAX, 0]),
                    encode_u32s(&[0])
                ),
                "bad_field:classes",
            ),
            (
                format!(
                    r#"{{"op":"coarse_screen","queries":"{}","classes":"{}","m":-3,"shards":"{}"}}"#,
                    encode_f32s(&vec![0.5; ds.proxy_d]),
                    encode_u32s(&[u32::MAX]),
                    encode_u32s(&[0])
                ),
                "bad_field:m",
            ),
            (
                format!(
                    r#"{{"op":"coarse_screen","queries":"{}","classes":"{}",{m_ok},"shards":"{}"}}"#,
                    encode_f32s(&vec![0.5; ds.proxy_d]),
                    encode_u32s(&[u32::MAX]),
                    encode_u32s(&[9])
                ),
                "bad_field:shards",
            ),
            (
                format!(
                    r#"{{"op":"warm_screen","query":"{}","class":1,{m_ok},"seeds":"{}","shards":"{}"}}"#,
                    encode_f32s(&vec![0.5; ds.proxy_d]),
                    encode_u32s(&[4, 4, 9]),
                    encode_u32s(&[0])
                ),
                "bad_field:seeds",
            ),
            (
                format!(
                    r#"{{"op":"masked_refine","queries":"{}","pools":["{}"],"k":3}}"#,
                    encode_f32s(&vec![0.5; ds.d]),
                    encode_u32s(&[90])
                ),
                "bad_field:pools",
            ),
        ];
        for (raw, want) in cases {
            let resp = call(&mut stream, &mut reader, &raw);
            assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "{raw}");
            assert_eq!(resp.get("error").and_then(Json::as_str), Some(want), "{raw}");
        }
        // non-JSON garbage is a parse error, not a dead stream
        let garbage = call(&mut stream, &mut reader, "{{{not json");
        assert_eq!(garbage.get("ok").and_then(Json::as_bool), Some(false));
        let pong = call(&mut stream, &mut reader, r#"{"op":"ping"}"#);
        assert_eq!(pong.get("pong").and_then(Json::as_bool), Some(true));
        w.stop();
    }

    #[test]
    fn expired_deadline_refuses_op_before_compute() {
        let ds = Arc::new(tiny(80, 3));
        let (mut w, be) = worker(&ds, 2);
        let (mut stream, mut reader) = connect(&w.addr);

        let scanned_before = be.stats().shards_scanned;
        let mut req = Json::obj();
        req.set("op", "coarse_screen")
            .set("queries", encode_f32s(&vec![0.1; ds.proxy_d]).as_str())
            .set("classes", encode_u32s(&[u32::MAX]).as_str())
            .set("m", 5_u64)
            .set("shards", encode_u32s(&[0, 1]).as_str())
            .set("deadline_ms", 0_u64);
        let resp = call(&mut stream, &mut reader, &req.to_string_compact());
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(
            resp.get("error").and_then(Json::as_str),
            Some("deadline_exceeded")
        );
        assert_eq!(
            be.stats().shards_scanned,
            scanned_before,
            "an expired op must not touch the scan path"
        );

        // without the deadline the same op succeeds on the same stream
        let mut ok_req = req.clone();
        if let Json::Obj(map) = &mut ok_req {
            map.remove("deadline_ms");
        }
        let ok = call(&mut stream, &mut reader, &ok_req.to_string_compact());
        assert_eq!(ok.get("ok").and_then(Json::as_bool), Some(true));
        w.stop();
    }

    #[test]
    fn warm_screen_and_masked_refine_round_trip_bit_exact() {
        let ds = Arc::new(tiny(160, 13));
        let (mut w, be) = worker(&ds, 4);
        let (mut stream, mut reader) = connect(&w.addr);

        let mut rng = crate::util::rng::Pcg64::new(29);
        let qp: Vec<f32> = (0..ds.proxy_d).map(|_| rng.normal()).collect();
        let seeds: Vec<u32> = (0..60).map(|i| i * 2).collect();
        let mut req = Json::obj();
        req.set("op", "warm_screen")
            .set("query", encode_f32s(&qp).as_str())
            .set("m", 12_u64)
            .set("seeds", encode_u32s(&seeds).as_str())
            .set("shards", encode_u32s(&[1, 3]).as_str());
        let resp = call(&mut stream, &mut reader, &req.to_string_compact());
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        let want = be.warm_scored(&ds, &qp, None, 12, &seeds, &[1, 3]);
        match want {
            Some(want) => {
                assert_eq!(resp.get("found").and_then(Json::as_bool), Some(true));
                let got = decode_scored(resp.get("result").unwrap().as_str().unwrap()).unwrap();
                assert_eq!(got, want);
            }
            None => {
                assert_eq!(resp.get("found").and_then(Json::as_bool), Some(false));
            }
        }

        let q: Vec<f32> = (0..ds.d).map(|_| rng.normal()).collect();
        let pool: Vec<u32> = (0..40u32).collect();
        let mut rreq = Json::obj();
        rreq.set("op", "masked_refine")
            .set("queries", encode_f32s(&q).as_str())
            .set("pools", Json::Arr(vec![Json::Str(encode_u32s(&pool))]))
            .set("k", 7_u64);
        let rresp = call(&mut stream, &mut reader, &rreq.to_string_compact());
        assert_eq!(rresp.get("ok").and_then(Json::as_bool), Some(true));
        let arr = rresp.get("results").unwrap().as_arr().unwrap();
        let got = decode_scored(arr[0].as_str().unwrap()).unwrap();
        let want = be.refine_scored(&ds, &[&q], &[&pool], 7);
        assert_eq!(vec![got], want);
        w.stop();
    }
}
