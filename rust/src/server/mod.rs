//! TCP line-JSON front end for the engine (one JSON document per line).
//!
//! Protocol:
//!   → {"op":"ping"}                                  ← {"ok":true,"pong":true}
//!   → {"op":"stats"}                                 ← {"ok":true,"stats":{…}}
//!   → {"op":"health"}                                ← {"ok":true,"status":"ok"|"degraded",…}
//!   → {"op":"generate","method":"golddiff","seed":1[,"class":3][,"deadline_ms":250]}
//!                                                    ← {"ok":true,"id":…,"sample":[…],…}
//! Queue-full responses carry `"ok":false,"error":"busy"` — the bounded
//! queue's backpressure surfaced to clients (HTTP-429 analogue). A request
//! that fails inside the engine answers `"ok":false` with the
//! machine-readable reason (`"deadline_exceeded"`, `"internal"`) and the
//! connection keeps serving.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::queue::SubmitError;
use crate::coordinator::request::{strict_u32_field, strict_u64_field};
use crate::coordinator::Engine;
use crate::denoiser::DenoiserKind;
use crate::util::json::{parse, Json};

pub mod worker;

/// A running server (owns the accept thread).
pub struct Server {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<std::sync::atomic::AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve `engine` until shutdown.
    pub fn start(engine: Arc<Engine>, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let sd = Arc::clone(&shutdown);
        let handle = std::thread::Builder::new()
            .name("golddiff-server".into())
            .spawn(move || {
                let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
                let mut accept_errs_logged = std::collections::HashSet::new();
                while !sd.load(std::sync::atomic::Ordering::Relaxed) {
                    // reap finished connection handles each iteration — a
                    // long-lived server would otherwise grow `conns` by one
                    // JoinHandle per client forever (joining a finished
                    // thread cannot block)
                    conns = conns
                        .into_iter()
                        .filter_map(|c| {
                            if c.is_finished() {
                                let _ = c.join();
                                None
                            } else {
                                Some(c)
                            }
                        })
                        .collect();
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let eng = Arc::clone(&engine);
                            let sd2 = Arc::clone(&sd);
                            conns.push(std::thread::spawn(move || {
                                let _ = handle_conn(stream, eng, sd2);
                            }));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(10));
                        }
                        Err(e) => {
                            // a transient accept failure (EMFILE, ECONNABORTED,
                            // …) must not kill the listener: log the first
                            // occurrence of each distinct ErrorKind — a
                            // once-ever latch would swallow a *different*
                            // failure cause hours later — back off briefly,
                            // keep accepting
                            if accept_errs_logged.insert(e.kind()) {
                                eprintln!("golddiff: server: accept failed ({e}); retrying");
                            }
                            std::thread::sleep(std::time::Duration::from_millis(50));
                        }
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
            })?;
        Ok(Server {
            addr: local,
            shutdown,
            handle: Some(handle),
        })
    }

    pub fn stop(mut self) {
        self.shutdown
            .store(true, std::sync::atomic::Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    engine: Arc<Engine>,
    shutdown: Arc<std::sync::atomic::AtomicBool>,
) -> Result<()> {
    // periodic read timeout so connection threads observe shutdown instead
    // of blocking forever in read_line (otherwise Server::stop deadlocks
    // joining a thread parked on a live but idle client)
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {
                let reply = match handle_line(line.trim(), &engine) {
                    Ok(j) => j,
                    Err(e) => {
                        let mut j = Json::obj();
                        j.set("ok", false).set("error", e.to_string());
                        j
                    }
                };
                line.clear();
                stream.write_all(reply.to_string_compact().as_bytes())?;
                stream.write_all(b"\n")?;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if shutdown.load(std::sync::atomic::Ordering::Relaxed) {
                    return Ok(());
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
}

fn handle_line(line: &str, engine: &Engine) -> Result<Json> {
    let req = parse(line)?;
    let op = req.str_field("op")?;
    match op {
        "ping" => {
            let mut j = Json::obj();
            j.set("ok", true)
                .set("pong", true)
                .set("preset", engine.preset.as_str());
            Ok(j)
        }
        "stats" => {
            let mut j = Json::obj();
            j.set("ok", true).set("stats", engine.stats_json());
            Ok(j)
        }
        "health" => {
            let mut j = engine.health_json();
            j.set("ok", true);
            Ok(j)
        }
        "generate" => {
            let method = req
                .get("method")
                .and_then(Json::as_str)
                .and_then(DenoiserKind::parse)
                .unwrap_or(DenoiserKind::GoldDiff);
            // strict numeric validation: a malformed field answers the
            // machine-readable {"ok":false,"error":"bad_field:<name>"}
            // (via the handle_conn error path) instead of saturating —
            // {"class":-1} used to silently generate class 0, and seeds
            // ≥ 2^53 silently lost precision through the f64 cast
            let seed = strict_u64_field(&req, "seed")?.unwrap_or(0);
            let class = strict_u32_field(&req, "class")?;
            let deadline_ms = strict_u64_field(&req, "deadline_ms")?;
            match engine.try_submit_with_deadline(method, seed, class, deadline_ms) {
                Ok(rx) => {
                    let resp = rx.recv().context("engine dropped request")?;
                    let mut j = Json::obj();
                    if let Some(err) = &resp.error {
                        // an engine-side failure is a clean protocol reply,
                        // not a connection error — the stream keeps serving
                        j.set("ok", false)
                            .set("id", resp.id)
                            .set("error", err.as_str());
                        return Ok(j);
                    }
                    j.set("ok", true)
                        .set("id", resp.id)
                        .set("latency_secs", resp.latency_secs)
                        .set("queue_secs", resp.queue_secs)
                        .set("steps", resp.steps.len())
                        .set("sample", resp.sample.as_slice());
                    Ok(j)
                }
                Err(SubmitError::Full) => {
                    let mut j = Json::obj();
                    j.set("ok", false).set("error", "busy");
                    Ok(j)
                }
                Err(SubmitError::Closed) => anyhow::bail!("engine shut down"),
            }
        }
        other => anyhow::bail!("unknown op `{other}`"),
    }
}

/// Blocking line-JSON client with a read timeout (a wedged server surfaces
/// as an error instead of hanging the caller forever) and an optional
/// jittered-backoff retry for `"busy"` rejections.
pub struct Client {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
    retry_rng: crate::util::rng::Pcg64,
}

/// Default client read timeout: generous enough for a cold engine start +
/// a full trajectory, finite so a hung server cannot park the caller.
const CLIENT_READ_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(30);

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(CLIENT_READ_TIMEOUT))?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            stream,
            retry_rng: crate::util::rng::Pcg64::new(0x601d),
        })
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.stream.write_all(req.to_string_compact().as_bytes())?;
        self.stream.write_all(b"\n")?;
        let mut line = String::new();
        let n = self
            .reader
            .read_line(&mut line)
            .context("reading server reply")?;
        if n == 0 {
            anyhow::bail!("connection closed by server");
        }
        parse(line.trim())
    }

    pub fn ping(&mut self) -> Result<bool> {
        let mut j = Json::obj();
        j.set("op", "ping");
        Ok(self.call(&j)?.get("pong").and_then(Json::as_bool) == Some(true))
    }

    pub fn generate(&mut self, method: &str, seed: u64, class: Option<u32>) -> Result<Json> {
        self.generate_with_deadline(method, seed, class, None)
    }

    pub fn generate_with_deadline(
        &mut self,
        method: &str,
        seed: u64,
        class: Option<u32>,
        deadline_ms: Option<u64>,
    ) -> Result<Json> {
        let mut j = Json::obj();
        j.set("op", "generate").set("method", method).set("seed", seed);
        if let Some(c) = class {
            j.set("class", c as usize);
        }
        if let Some(dl) = deadline_ms {
            j.set("deadline_ms", dl);
        }
        self.call(&j)
    }

    /// `generate`, retrying `"busy"` rejections up to `max_retries` times
    /// with jittered exponential backoff (2ms doubling, capped at 500ms).
    /// Any reply other than busy — success or a hard failure — returns
    /// immediately.
    pub fn generate_with_retry(
        &mut self,
        method: &str,
        seed: u64,
        class: Option<u32>,
        max_retries: u32,
    ) -> Result<Json> {
        let mut backoff_ms: u64 = 2;
        for attempt in 0..=max_retries {
            let resp = self.generate(method, seed, class)?;
            let busy = resp.get("ok").and_then(Json::as_bool) == Some(false)
                && resp.get("error").and_then(Json::as_str) == Some("busy");
            if !busy || attempt == max_retries {
                return Ok(resp);
            }
            // full jitter: sleep uniformly in [0, backoff) so retrying
            // clients spread out instead of re-colliding in lockstep
            let jittered = self.retry_rng.below(backoff_ms.max(1) as usize) as u64;
            std::thread::sleep(std::time::Duration::from_millis(jittered));
            backoff_ms = (backoff_ms * 2).min(500);
        }
        unreachable!("loop returns on the last attempt")
    }

    pub fn stats(&mut self) -> Result<Json> {
        let mut j = Json::obj();
        j.set("op", "stats");
        self.call(&j)
    }

    pub fn health(&mut self) -> Result<Json> {
        let mut j = Json::obj();
        j.set("op", "health");
        self.call(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;

    #[test]
    fn serves_ping_generate_stats_over_tcp() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let cfg = EngineConfig {
            preset: "moons".into(),
            data_dir: std::env::temp_dir().join("golddiff_server_test"),
            ..Default::default()
        };
        let engine = Arc::new(Engine::start(cfg).unwrap());
        let server = Server::start(Arc::clone(&engine), "127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr).unwrap();

        assert!(client.ping().unwrap());

        let resp = client.generate("golddiff", 3, None).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(resp.get("sample").unwrap().as_arr().unwrap().len(), 2);

        let stats = client.stats().unwrap();
        assert!(
            stats
                .get("stats")
                .unwrap()
                .get("completed")
                .unwrap()
                .as_f64()
                .unwrap()
                >= 1.0
        );

        let bad = client
            .call(&crate::util::json::parse(r#"{"op":"wat"}"#).unwrap())
            .unwrap();
        assert_eq!(bad.get("ok").and_then(Json::as_bool), Some(false));

        // malformed numeric fields answer a clean bad_field error and the
        // connection keeps serving (PR-8 validation regression)
        for (raw, want) in [
            (r#"{"op":"generate","class":-1}"#, "bad_field:class"),
            (
                r#"{"op":"generate","seed":9007199254740992}"#,
                "bad_field:seed",
            ),
            (r#"{"op":"generate","deadline_ms":0.5}"#, "bad_field:deadline_ms"),
        ] {
            let resp = client
                .call(&crate::util::json::parse(raw).unwrap())
                .unwrap();
            assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
            assert_eq!(resp.get("error").and_then(Json::as_str), Some(want));
        }
        assert!(client.ping().unwrap(), "stream survives rejected requests");

        server.stop();
    }

    #[test]
    fn health_deadline_and_panic_paths_over_tcp() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let cfg = EngineConfig {
            preset: "moons".into(),
            data_dir: std::env::temp_dir().join("golddiff_server_fault_test"),
            ..Default::default()
        };
        let engine = Arc::new(Engine::start(cfg).unwrap());
        let server = Server::start(Arc::clone(&engine), "127.0.0.1:0").unwrap();
        let mut client = Client::connect(&server.addr).unwrap();

        // a clean start reports healthy with no degraded tiers
        let h = client.health().unwrap();
        assert_eq!(h.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(h.get("status").and_then(Json::as_str), Some("ok"));
        assert!(h.get("degraded_tiers").unwrap().as_arr().unwrap().is_empty());

        // an already-expired deadline answers deadline_exceeded, not a hang
        let late = client
            .generate_with_deadline("golddiff", 3, None, Some(0))
            .unwrap();
        assert_eq!(late.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(
            late.get("error").and_then(Json::as_str),
            Some("deadline_exceeded")
        );

        // a panicking request (out-of-range class) answers "internal" and
        // the SAME connection keeps serving afterwards
        let boom = client.generate("golddiff", 5, Some(9999)).unwrap();
        assert_eq!(boom.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(boom.get("error").and_then(Json::as_str), Some("internal"));
        let ok = client.generate_with_retry("golddiff", 5, None, 3).unwrap();
        assert_eq!(ok.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(ok.get("sample").unwrap().as_arr().unwrap().len(), 2);

        // the health op reflects the recovered panic + expired deadline
        let h2 = client.health().unwrap();
        assert!(h2.get("panics_recovered").unwrap().as_f64().unwrap() >= 1.0);
        assert!(h2.get("deadline_expired").unwrap().as_f64().unwrap() >= 1.0);

        server.stop();
    }
}
