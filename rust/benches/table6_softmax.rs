//! Regenerates the paper's Table 6 (+ Fig. 2 quantification): biased WSS vs
//! unbiased SS weight estimation inside GoldDiff, with high-frequency
//! energy retention of generated samples.
fn main() -> anyhow::Result<()> {
    golddiff::benchlib::experiments::run_table6(0)?;
    Ok(())
}
