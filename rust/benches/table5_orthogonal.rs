//! Regenerates the paper's Table 5: orthogonality — GoldDiff plugged into
//! the Optimal and Kamb baselines on CelebA-HQ / AFHQ stand-ins.
fn main() -> anyhow::Result<()> {
    golddiff::benchlib::experiments::run_table5(0)?;
    Ok(())
}
