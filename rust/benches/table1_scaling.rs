//! Regenerates the paper's Table 1: algorithmic complexity, measured as the
//! empirical per-step cost vs dataset size N plus fitted log-log slopes.
//! Run: cargo bench --bench table1_scaling   (GOLDDIFF_EVAL_SAMPLES scales effort)
fn main() -> anyhow::Result<()> {
    let sizes = if std::env::var("GOLDDIFF_FULL").is_ok() {
        vec![2_500usize, 5_000, 10_000, 20_000, 40_000]
    } else {
        vec![2_500usize, 5_000, 10_000, 20_000]
    };
    golddiff::benchlib::experiments::run_table1(&sizes, 0)?;
    Ok(())
}
