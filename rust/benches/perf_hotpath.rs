//! §Perf microbenchmarks for the serving hot path (EXPERIMENTS.md §Perf):
//!
//!   0. retrieval backends — batched-vs-per-query multi-query scanning,
//!      the register-tiled kernel vs the scalar batched pass
//!      (`kernel_tiled_vs_scalar`, with rows-per-pass and tiles-evaluated
//!      telemetry), the batched refine ladder vs per-query refines, and
//!      cluster-pruned-vs-flat screening, and shard-parallel retrieval vs
//!      the monolithic scan (`shard_scan_scaling` / `sharded_vs_monolithic`,
//!      exact-merge parity asserted before timing), and the quantised
//!      screen/refine tier vs the pure-f32 kernel plus SIMD-vs-scalar
//!      accumulator lanes (`quant_screen_vs_f32` / `simd_vs_scalar`,
//!      byte-equality asserted before timing) — all run without the
//!      XLA runtime, emit machine-readable `BENCH {json}` lines and
//!      *verify* the one-pass-per-group invariant via the backend pass
//!      counter; plus the Gaussian-score fast path vs the retrieval tick
//!      it replaces (`gauss_vs_retrieval`, retrieval-segment byte-equality
//!      asserted before timing);
//!   1. coarse proxy scan throughput (rows/s) vs thread count;
//!   2. exact refine top-k inside the candidate pool;
//!   3. gather + upload of the golden subset;
//!   4. PJRT dispatch of golden_step per k-bucket (Pallas streaming kernel);
//!   5. golden_step (Pallas) vs golden_step_jnp (pure-XLA twin) — the
//!      L1-vs-L2 structural comparison;
//!   6. end-to-end XLA-backed step breakdown per method.
//!
//! Sections 3–6 need compiled artifacts and are skipped (with a notice)
//! when the runtime cannot be opened, so CI can smoke-run the retrieval
//! comparisons on a bare checkout. `GOLDDIFF_BENCH_N` shrinks the corpus
//! for smoke runs.

use std::time::Instant;

use golddiff::benchlib;
use golddiff::denoiser::StepContext;
use golddiff::index::backend::{
    BackendOpts, BatchedScan, ClusterPruned, FlatScan, ProxyQuery, RetrievalBackend,
    RetrievalBackendKind,
};
use golddiff::index::scan::ProxyIndex;
use golddiff::index::shard::ShardedBackend;
use golddiff::schedule::noise::{NoiseSchedule, ScheduleKind};
use golddiff::util::timer::TimingStats;

fn bench<F: FnMut()>(label: &str, iters: usize, mut f: F) -> f64 {
    f(); // warmup (compiles executables on first use)
    let mut t = TimingStats::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        t.record(t0.elapsed());
    }
    println!(
        "{label:58} {:>10.3} ms  (min {:.3} ms, n={iters})",
        t.mean() * 1e3,
        t.min() * 1e3
    );
    t.mean()
}

/// Section 0: the pluggable retrieval backends, no runtime required.
fn bench_retrieval_backends(ds: &golddiff::Dataset) {
    const BATCH: usize = 8;
    let m = ds.n / 4;
    let mut rng = golddiff::util::rng::Pcg64::new(7);
    // realistic queries: proxy embeds of noise-perturbed corpus rows
    let queries_data: Vec<Vec<f32>> = (0..BATCH)
        .map(|_| {
            let row = ds.proxy_row(rng.below(ds.n)).to_vec();
            row.iter().map(|&v| v + rng.normal() * 0.3).collect()
        })
        .collect();
    let queries: Vec<ProxyQuery> = queries_data
        .iter()
        .map(|q| ProxyQuery {
            proxy: q,
            class: None,
        })
        .collect();

    let flat = FlatScan::new(golddiff::util::threadpool::default_threads());
    let batched = BatchedScan::default();

    println!("-- retrieval backends (batch={BATCH}, m={m}) --");
    let t_flat = bench(&format!("flat scan x{BATCH} (one pass per query)"), 15, || {
        for q in &queries {
            let _ = flat.top_m(ds, q.proxy, m, q.class);
        }
    });
    batched.reset_stats();
    let t_batched = bench(&format!("batched scan x{BATCH} (one pass per group)"), 15, || {
        let _ = batched.top_m_batch(ds, &queries, m);
    });
    // one warmup + 15 timed calls — the pass counter must show exactly one
    // proxy-table pass per batched call, i.e. the whole group shares a pass
    let snap = batched.stats();
    assert_eq!(
        snap.proxy_passes, 16,
        "batched scan must pay exactly one pass per group call"
    );
    assert_eq!(snap.queries, 16 * BATCH as u64);
    assert!(
        snap.tiles_evaluated > 0,
        "the default batched scan must run through the tiled kernel"
    );
    let speedup = t_flat / t_batched.max(1e-12);
    println!("{:>58}  -> batched speedup {speedup:.2}x at batch {BATCH}", "");
    benchlib::emit_bench(
        "retrieval_batched_vs_flat",
        &[
            ("batch", BATCH as f64),
            ("m", m as f64),
            ("n", ds.n as f64),
            ("flat_secs", t_flat),
            ("batched_secs", t_batched),
            ("speedup", speedup),
            ("passes_per_group", 1.0),
        ],
    );

    // register-tiled kernel vs the PR 1 scalar batched pass: identical
    // pass structure (one traversal per group), different inner loop
    let scalar = BatchedScan::scalar(golddiff::util::threadpool::default_threads());
    let t_scalar = bench(
        &format!("kernel_scalar batched x{BATCH} (PR 1 row-major)"),
        15,
        || {
            let _ = scalar.top_m_batch(ds, &queries, m);
        },
    );
    let kernel_speedup = t_scalar / t_batched.max(1e-12);
    let rows_per_pass = snap.rows_scanned as f64 / snap.proxy_passes.max(1) as f64;
    println!(
        "{:>58}  -> kernel_tiled speedup {kernel_speedup:.2}x, {rows_per_pass:.0} rows/pass, {} tiles",
        "", snap.tiles_evaluated
    );
    benchlib::emit_bench(
        "kernel_tiled_vs_scalar",
        &[
            ("batch", BATCH as f64),
            ("m", m as f64),
            ("n", ds.n as f64),
            ("tiled_secs", t_batched),
            ("scalar_secs", t_scalar),
            ("speedup", kernel_speedup),
            ("rows_per_pass", rows_per_pass),
            ("tiles_evaluated", snap.tiles_evaluated as f64),
            ("kernel_exits", snap.kernel_exits as f64),
        ],
    );

    // heap-aware block ordering vs storage order: same kernel pass, the
    // ordered scan visits blocks nearest the query-group mean first so the
    // strip bound engages early (precision budgets show the gap)
    let m_ord = (ds.n / 20).max(1);
    let unordered = BatchedScan::new(golddiff::util::threadpool::default_threads())
        .with_ordering(false);
    let t_unord = bench(
        &format!("batched scan top-{m_ord} x{BATCH} (storage order)"),
        15,
        || {
            let _ = unordered.top_m_batch(ds, &queries, m_ord);
        },
    );
    batched.reset_stats();
    let t_ord = bench(
        &format!("batched scan top-{m_ord} x{BATCH} (heap-aware order)"),
        15,
        || {
            let _ = batched.top_m_batch(ds, &queries, m_ord);
        },
    );
    let osnap = batched.stats();
    assert!(
        osnap.blocks_reordered > 0,
        "the default batched scan must reorder blocks"
    );
    let order_speedup = t_unord / t_ord.max(1e-12);
    println!(
        "{:>58}  -> ordered speedup {order_speedup:.2}x, {} blocks reordered, {} exit-gain rows",
        "", osnap.blocks_reordered, osnap.exit_gain_rows
    );
    benchlib::emit_bench(
        "scan_ordered_vs_unordered",
        &[
            ("batch", BATCH as f64),
            ("m", m_ord as f64),
            ("n", ds.n as f64),
            ("unordered_secs", t_unord),
            ("ordered_secs", t_ord),
            ("speedup", order_speedup),
            ("blocks_reordered", osnap.blocks_reordered as f64),
            ("exit_gain_rows", osnap.exit_gain_rows as f64),
        ],
    );

    // batched refine ladder vs per-query refine over the same pools
    let full_queries: Vec<Vec<f32>> = (0..BATCH)
        .map(|_| {
            let row = ds.row(rng.below(ds.n)).to_vec();
            row.iter().map(|&v| v + rng.normal() * 0.2).collect()
        })
        .collect();
    let fq_proxies: Vec<Vec<f32>> = full_queries
        .iter()
        .map(|q| golddiff::data::synthetic::proxy_embed(q, ds.h, ds.w, ds.c))
        .collect();
    let pq: Vec<ProxyQuery> = fq_proxies
        .iter()
        .map(|p| ProxyQuery {
            proxy: p,
            class: None,
        })
        .collect();
    let pools = batched.top_m_batch(ds, &pq, m);
    let k = (ds.n / 20).max(1);
    let t_per = bench(&format!("refine per-query x{BATCH} top-{k}"), 15, || {
        for (q, pool) in full_queries.iter().zip(&pools) {
            let _ = flat.refine_top_k(ds, q, pool, k);
        }
    });
    let qrefs: Vec<&[f32]> = full_queries.iter().map(|q| q.as_slice()).collect();
    let poolrefs: Vec<&[u32]> = pools.iter().map(|p| p.as_slice()).collect();
    let t_ladder = bench(&format!("refine ladder x{BATCH} top-{k} (union scan)"), 15, || {
        let _ = batched.refine_top_k_batch(ds, &qrefs, &poolrefs, k);
    });
    let ladder_speedup = t_per / t_ladder.max(1e-12);
    // per-call union size: reset, run once, snapshot (the timed loop above
    // accumulates the counter across every iteration)
    batched.reset_stats();
    let _ = batched.refine_top_k_batch(ds, &qrefs, &poolrefs, k);
    let refine_rows = batched.stats().refine_rows;
    println!(
        "{:>58}  -> ladder speedup {ladder_speedup:.2}x at batch {BATCH}, {refine_rows} union rows",
        ""
    );
    benchlib::emit_bench(
        "refine_ladder_batched_vs_perquery",
        &[
            ("batch", BATCH as f64),
            ("m", m as f64),
            ("k", k as f64),
            ("perquery_secs", t_per),
            ("ladder_secs", t_ladder),
            ("speedup", ladder_speedup),
            ("refine_rows", refine_rows as f64),
        ],
    );

    // pre-blocked refine (default, masked kernel tiles over row_blocks) vs
    // the row-major reference ladder on the identical pools
    let rowmajor = BatchedScan::new(golddiff::util::threadpool::default_threads())
        .with_refine_kernel(false);
    let t_rowmajor = bench(
        &format!("refine ladder x{BATCH} top-{k} (row-major)"),
        15,
        || {
            let _ = rowmajor.refine_top_k_batch(ds, &qrefs, &poolrefs, k);
        },
    );
    let preblocked_speedup = t_rowmajor / t_ladder.max(1e-12);
    batched.reset_stats();
    let _ = batched.refine_top_k_batch(ds, &qrefs, &poolrefs, k);
    let rsnap = batched.stats();
    assert!(
        rsnap.tiles_evaluated > 0,
        "the default refine must run through the masked kernel tiles"
    );
    println!(
        "{:>58}  -> preblocked speedup {preblocked_speedup:.2}x, {} tiles, {} exits",
        "", rsnap.tiles_evaluated, rsnap.kernel_exits
    );
    benchlib::emit_bench(
        "refine_preblocked_vs_rowmajor",
        &[
            ("batch", BATCH as f64),
            ("m", m as f64),
            ("k", k as f64),
            ("rowmajor_secs", t_rowmajor),
            ("preblocked_secs", t_ladder),
            ("speedup", preblocked_speedup),
            ("refine_rows", rsnap.refine_rows as f64),
            ("tiles_evaluated", rsnap.tiles_evaluated as f64),
            ("kernel_exits", rsnap.kernel_exits as f64),
        ],
    );

    // cluster-pruned screening vs the flat reference (exact mode)
    let t_build = Instant::now();
    let cp = ClusterPruned::build(ds, 64, 0, 0);
    let build_secs = t_build.elapsed().as_secs_f64();
    println!(
        "{:58} {:>10.3} ms  (one-time)",
        "cluster-pruned build (64 lists)",
        build_secs * 1e3
    );
    // exactness spot-check before timing: pruned results match the flat
    // scan rank-by-rank in distance (ids may swap only on exact f32 ties,
    // which reorder by scan order — see index/README.md)
    let pdist = |qp: &[f32], gid: u32| -> f32 {
        ds.proxy_row(gid as usize)
            .iter()
            .zip(qp)
            .map(|(a, b)| (a - b) * (a - b))
            .sum()
    };
    for q in &queries {
        let got = cp.top_m(ds, q.proxy, m, q.class);
        let want = flat.top_m(ds, q.proxy, m, q.class);
        assert_eq!(got.len(), want.len(), "cluster-pruned must fill top-m");
        for (rank, (a, b)) in got.iter().zip(&want).enumerate() {
            let (da, db) = (pdist(q.proxy, *a), pdist(q.proxy, *b));
            assert!(
                (da - db).abs() <= 1e-5 * (1.0 + da.abs()),
                "cluster-pruned diverged from flat at rank {rank}: {da} vs {db}"
            );
        }
    }
    // prune effectiveness shows at precision budgets (small m, low noise)
    for m_small in [ds.n / 20, ds.n / 100] {
        cp.reset_stats();
        let t_cp = bench(&format!("cluster-pruned top-{m_small}"), 15, || {
            for q in &queries {
                let _ = cp.top_m(ds, q.proxy, m_small, q.class);
            }
        });
        let t_fl = bench(&format!("flat scan top-{m_small}"), 15, || {
            for q in &queries {
                let _ = flat.top_m(ds, q.proxy, m_small, q.class);
            }
        });
        let snap = cp.stats();
        let total_lists = (snap.clusters_scanned + snap.clusters_pruned).max(1);
        let pruned_frac = snap.clusters_pruned as f64 / total_lists as f64;
        let rows_frac = snap.rows_scanned as f64 / (snap.queries as f64 * ds.n as f64);
        println!(
            "{:>58}  -> {:.0}% lists pruned, {:.0}% rows scanned, {:.2}x vs flat",
            "",
            pruned_frac * 100.0,
            rows_frac * 100.0,
            t_fl / t_cp.max(1e-12)
        );
        benchlib::emit_bench(
            "retrieval_cluster_vs_flat",
            &[
                ("m", m_small as f64),
                ("n", ds.n as f64),
                ("lists", 64.0),
                ("cluster_secs", t_cp),
                ("flat_secs", t_fl),
                ("speedup", t_fl / t_cp.max(1e-12)),
                ("pruned_frac", pruned_frac),
                ("rows_scanned_frac", rows_frac),
            ],
        );
    }
}

/// Section 0c: shard-parallel retrieval vs the monolithic batched scan (no
/// runtime required). Each shard count runs the identical query group; the
/// spot-check pins the exact-merge contract (byte-identical ids) before
/// any timing is trusted.
fn bench_sharded(ds: &golddiff::Dataset) {
    const BATCH: usize = 8;
    let m = (ds.n / 10).max(1);
    let mut rng = golddiff::util::rng::Pcg64::new(23);
    let queries_data: Vec<Vec<f32>> = (0..BATCH)
        .map(|_| {
            let row = ds.proxy_row(rng.below(ds.n)).to_vec();
            row.iter().map(|&v| v + rng.normal() * 0.3).collect()
        })
        .collect();
    let queries: Vec<ProxyQuery> = queries_data
        .iter()
        .map(|q| ProxyQuery {
            proxy: q,
            class: None,
        })
        .collect();

    println!("-- sharded retrieval (batch={BATCH}, m={m}) --");
    let mono = BatchedScan::default();
    let t_mono = bench(&format!("monolithic batched scan x{BATCH}"), 15, || {
        let _ = mono.top_m_batch(ds, &queries, m);
    });
    let want = mono.top_m_batch(ds, &queries, m);

    let mut t_one = f64::NAN;
    for shards in [1usize, 2, 4, 8] {
        let sb = ShardedBackend::build(
            ds,
            RetrievalBackendKind::Batched,
            BackendOpts {
                shards,
                ..BackendOpts::default()
            },
        );
        // exact-merge contract: identical ids for every shard count
        assert_eq!(
            sb.top_m_batch(ds, &queries, m),
            want,
            "sharded scan must match the monolithic scan at shards={shards}"
        );
        sb.reset_stats();
        let t = bench(&format!("sharded batched scan x{BATCH} (shards={shards})"), 15, || {
            let _ = sb.top_m_batch(ds, &queries, m);
        });
        if shards == 1 {
            t_one = t;
        }
        let snap = sb.stats();
        println!(
            "{:>58}  -> {:.2}x vs 1 shard, {} (query,shard) scans",
            "",
            t_one / t.max(1e-12),
            snap.shards_scanned
        );
        benchlib::emit_bench(
            "shard_scan_scaling",
            &[
                ("shards", shards as f64),
                ("batch", BATCH as f64),
                ("m", m as f64),
                ("n", ds.n as f64),
                ("secs", t),
                ("speedup_vs_1shard", t_one / t.max(1e-12)),
                ("shards_scanned", snap.shards_scanned as f64),
                ("shards_skipped", snap.shards_skipped as f64),
            ],
        );
        if shards == 4 {
            benchlib::emit_bench(
                "sharded_vs_monolithic",
                &[
                    ("shards", shards as f64),
                    ("batch", BATCH as f64),
                    ("m", m as f64),
                    ("n", ds.n as f64),
                    ("monolithic_secs", t_mono),
                    ("sharded_secs", t),
                    ("speedup", t_mono / t.max(1e-12)),
                ],
            );
        }
    }
}

/// Section 0b: the concentration warm-start vs the cold screen (no runtime
/// required). A tick group's golden subsets at sampling point t−1 seed the
/// screens at t; the seeded screen skips every proxy block the exact
/// centroid bound clears.
fn bench_warm_start(ds: &golddiff::Dataset, sched: &NoiseSchedule) {
    use golddiff::denoiser::golddiff::{
        blended_golden_rows_batch, blended_golden_rows_batch_warm, WarmStart,
    };

    const BATCH: usize = 8;
    let backend = BatchedScan::default();
    let buckets: Vec<usize> = (5..=17).map(|p| 1usize << p).collect();
    let budget = golddiff::schedule::budget::BudgetSchedule::paper_defaults(ds.n, &buckets);
    let step = sched.steps - 1; // largest m — the hardest screen to warm
    let b = budget.at(sched, step);
    let b_prev = budget.at(sched, step - 1);

    let xs_data: Vec<Vec<f32>> = (0..BATCH as u64)
        .map(|i| {
            let mut r = golddiff::util::rng::Pcg64::new(400 + i);
            let row = ds.row(r.below(ds.n)).to_vec();
            row.iter().map(|&v| v + r.normal() * 0.2).collect()
        })
        .collect();
    let xs: Vec<&[f32]> = xs_data.iter().map(|x| x.as_slice()).collect();
    let ctx = StepContext {
        ds,
        sched,
        step,
        class: None,
    };
    let ctxs: Vec<&StepContext> = xs.iter().map(|_| &ctx).collect();

    println!("-- concentration warm-start (batch={BATCH}, m={}, k={}) --", b.m, b.k);
    let t_cold = bench(&format!("cold screen x{BATCH} t={step}"), 15, || {
        let _ = blended_golden_rows_batch(&backend, &ctxs, &xs, b.m, b.k, ds.h, ds.w, ds.c);
    });

    // seed with the previous sampling point's golden subsets, as the
    // engine's tick loop would
    let ctx_prev = StepContext {
        ds,
        sched,
        step: step - 1,
        class: None,
    };
    let ctxs_prev: Vec<&StepContext> = xs.iter().map(|_| &ctx_prev).collect();
    let prev = blended_golden_rows_batch(
        &backend, &ctxs_prev, &xs, b_prev.m, b_prev.k, ds.h, ds.w, ds.c,
    );
    let mut warm = WarmStart::new();
    warm.record(step - 1, &prev);
    let t_warm = bench(&format!("warm screen x{BATCH} t={step}"), 15, || {
        let _ = blended_golden_rows_batch_warm(
            &backend,
            &ctxs,
            &xs,
            b.m,
            b.k,
            ds.h,
            ds.w,
            ds.c,
            Some(&mut warm),
        );
    });
    let speedup = t_cold / t_warm.max(1e-12);
    let engaged = warm.hits as f64 / (warm.hits + warm.fallbacks).max(1) as f64;
    println!(
        "{:>58}  -> warm speedup {speedup:.2}x, {:.0}% screens seeded ({} hits / {} fallbacks)",
        "",
        engaged * 100.0,
        warm.hits,
        warm.fallbacks
    );
    benchlib::emit_bench(
        "warm_start_vs_cold",
        &[
            ("batch", BATCH as f64),
            ("m", b.m as f64),
            ("k", b.k as f64),
            ("n", ds.n as f64),
            ("cold_secs", t_cold),
            ("warm_secs", t_warm),
            ("speedup", speedup),
            ("warm_hits", warm.hits as f64),
            ("warm_fallbacks", warm.fallbacks as f64),
            ("engaged_frac", engaged),
        ],
    );
}

/// Section 0h: the Gaussian-score fast path — a closed-form high-noise
/// tick vs the full retrieval tick it replaces (no runtime required).
/// Before timing, the retrieval-segment contract is asserted: with a
/// forced switch point, every tick at or beyond the switch is
/// byte-identical to the gauss-off cell.
fn bench_gauss(ds: &golddiff::Dataset, sched: &NoiseSchedule) {
    use golddiff::denoiser::golddiff::{BaseWeighting, GoldDiff};
    use golddiff::denoiser::Denoiser;

    const SWITCH: usize = 3;
    let build = |switch: usize| {
        GoldDiff::paper_defaults(ds, sched, BaseWeighting::Golden)
            .with_backend(std::sync::Arc::new(BatchedScan::default()))
            .with_warm_start(false)
            .with_gauss(switch)
    };
    let mut rng = golddiff::util::rng::Pcg64::new(61);
    let xs: Vec<Vec<f32>> = (0..sched.steps)
        .map(|_| (0..ds.d).map(|_| rng.normal()).collect())
        .collect();

    // exactness first: the fast path substitutes the prefix and must not
    // perturb a single retrieval tick at or beyond the switch
    let mut off = build(0);
    let mut on = build(SWITCH);
    for step in SWITCH..sched.steps {
        let ctx = StepContext {
            ds,
            sched,
            step,
            class: None,
        };
        let a = off.denoise(&xs[step], &ctx);
        let b = on.denoise(&xs[step], &ctx);
        assert_eq!(
            a.f_hat, b.f_hat,
            "step {step}: gauss must leave the retrieval segment byte-identical"
        );
    }

    println!("-- gaussian fast path (switch={SWITCH}, n={}) --", ds.n);
    let ctx0 = StepContext {
        ds,
        sched,
        step: 0,
        class: None,
    };
    let mut gauss = build(SWITCH);
    let t_gauss = bench("gauss closed-form tick t=0", 30, || {
        let _ = gauss.denoise(&xs[0], &ctx0);
    });
    let mut retr = build(0);
    let t_retr = bench("full retrieval tick t=0", 30, || {
        let _ = retr.denoise(&xs[0], &ctx0);
    });
    let speedup = t_retr / t_gauss.max(1e-12);
    println!("{:>58}  -> gauss speedup {speedup:.2}x per tick", "");
    benchlib::emit_bench(
        "gauss_vs_retrieval",
        &[
            ("n", ds.n as f64),
            ("switch", SWITCH as f64),
            ("gauss_secs", t_gauss),
            ("retrieval_secs", t_retr),
            ("speedup", speedup),
        ],
    );
}

/// Section 0i: few-step sampling — a heun trajectory on a churn-budgeted
/// half grid vs the full-grid ddim path, both scored against a 4× finer
/// ddim reference (no runtime required). The subset-reuse corrector makes
/// a second-order tick cost ~one coarse screen instead of two, so the
/// budgeted heun run must serve ≥2× fewer screens while staying at
/// matched quality against the reference.
fn bench_fewstep(ds: &golddiff::Dataset, sched: &NoiseSchedule) {
    use golddiff::denoiser::golddiff::{BaseWeighting, GoldDiff};
    use golddiff::denoiser::Denoiser;
    use golddiff::sampler::{self, SamplerOpts, Solver};
    use golddiff::schedule::steps::{churn_prior, StepPlan};

    const SEED: u64 = 71;
    let backend = std::sync::Arc::new(BatchedScan::default());
    let mut run = |solver: Solver, sched: &NoiseSchedule, plan: &StepPlan| {
        let mut den = GoldDiff::paper_defaults(ds, sched, BaseWeighting::Golden)
            .with_backend(backend.clone())
            .with_warm_start(true);
        backend.reset_stats();
        let t0 = std::time::Instant::now();
        let t = sampler::sample_planned(
            &mut den as &mut dyn Denoiser,
            ds,
            sched,
            SEED,
            SamplerOpts {
                solver,
                ..SamplerOpts::default()
            },
            plan,
        );
        let secs = t0.elapsed().as_secs_f64();
        (t.final_sample().to_vec(), backend.stats().proxy_passes, secs)
    };

    // the quality reference: ddim on a 4× finer grid, same initial noise
    let fine = NoiseSchedule::new(ScheduleKind::DdpmLinear, 4 * sched.steps);
    let (x_ref, _, _) = run(Solver::Ddim, &fine, &StepPlan::full(fine.steps));
    let err = |x: &[f32]| -> f64 {
        x.iter()
            .zip(&x_ref)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    };

    let (x_full, screens_full, secs_full) =
        run(Solver::Ddim, sched, &StepPlan::full(sched.steps));
    let budget = sched.steps / 2;
    let plan = StepPlan::budgeted(sched, budget, 0, &churn_prior(sched));
    assert_eq!(plan.len(), budget, "the budget places exactly `budget` ticks");
    let (x_few, screens_few, secs_few) = run(Solver::Heun, sched, &plan);

    let err_full = err(&x_full);
    let err_few = err(&x_few);
    assert!(
        screens_full >= 2 * screens_few,
        "heun on a half budget must serve ≥2× fewer screens: \
         full {screens_full} vs few {screens_few}"
    );
    assert!(
        err_few <= err_full * 1.5 + 1e-3,
        "the budgeted heun run must hold matched quality: \
         err_few {err_few:.5} vs err_full {err_full:.5}"
    );
    let ratio = screens_full as f64 / screens_few.max(1) as f64;
    println!(
        "-- few-step sampling (ddim x{} grid vs heun x{} budget) --",
        sched.steps,
        plan.len()
    );
    println!(
        "{:>58}  -> {ratio:.1}x fewer screens, err {err_few:.4} vs {err_full:.4}",
        ""
    );
    benchlib::emit_bench(
        "fewstep_vs_fullgrid",
        &[
            ("n", ds.n as f64),
            ("steps", sched.steps as f64),
            ("budget", plan.len() as f64),
            ("screens_full", screens_full as f64),
            ("screens_few", screens_few as f64),
            ("screen_ratio", ratio),
            ("err_full", err_full),
            ("err_few", err_few),
            ("full_secs", secs_full),
            ("fewstep_secs", secs_few),
        ],
    );
}

/// Section 0d: out-of-core serving — the streamed (`open_streaming`,
/// bounded LRU) corpus vs the resident one on the identical retrieval
/// work (no runtime required). Byte-equality is asserted before timing;
/// the BENCH line carries the residency telemetry.
fn bench_streamed(ds: &golddiff::Dataset) {
    use golddiff::data::store;

    const BATCH: usize = 8;
    let shards = 8;
    let dir = std::env::temp_dir().join("golddiff_bench_streamed");
    std::fs::remove_dir_all(&dir).ok();
    let path = store::store_path(&dir, "bench-corpus");
    store::save_sharded(ds, &path, shards).unwrap();
    // budget ≈ a quarter of the blocked corpus so the LRU actually cycles
    let budget_mb = ((ds.n * ds.d * 4) / (1024 * 1024) / 4).max(1);
    let streamed = store::open_streaming(&path, shards, budget_mb).unwrap();

    let m = (ds.n / 10).max(1);
    let k = (ds.n / 20).max(1);
    let mut rng = golddiff::util::rng::Pcg64::new(41);
    let queries_data: Vec<Vec<f32>> = (0..BATCH)
        .map(|_| {
            let row = ds.proxy_row(rng.below(ds.n)).to_vec();
            row.iter().map(|&v| v + rng.normal() * 0.3).collect()
        })
        .collect();
    let queries: Vec<ProxyQuery> = queries_data
        .iter()
        .map(|q| ProxyQuery {
            proxy: q,
            class: None,
        })
        .collect();
    let full_queries: Vec<Vec<f32>> = (0..BATCH as u64)
        .map(|i| {
            let mut r = golddiff::util::rng::Pcg64::new(600 + i);
            (0..ds.d).map(|_| r.normal()).collect()
        })
        .collect();

    println!("-- streamed vs resident corpus (shards={shards}, budget={budget_mb} MiB) --");
    let resident_backend = BatchedScan::default();
    let streamed_backend = BatchedScan::default();
    // the coarse screen reads the resident proxies either way; pools are
    // identical, so the refine comparison is apples-to-apples
    let pools = resident_backend.top_m_batch(ds, &queries, m);
    assert_eq!(
        streamed_backend.top_m_batch(&streamed, &queries, m),
        pools,
        "streamed coarse screen must equal resident"
    );
    let qrefs: Vec<&[f32]> = full_queries.iter().map(|q| q.as_slice()).collect();
    let poolrefs: Vec<&[u32]> = pools.iter().map(|p| p.as_slice()).collect();
    assert_eq!(
        streamed_backend.refine_top_k_batch(&streamed, &qrefs, &poolrefs, k),
        resident_backend.refine_top_k_batch(ds, &qrefs, &poolrefs, k),
        "streamed refine must equal resident byte-for-byte"
    );
    let t_res = bench(&format!("refine x{BATCH} top-{k} (resident corpus)"), 15, || {
        let _ = resident_backend.refine_top_k_batch(ds, &qrefs, &poolrefs, k);
    });
    let t_str = bench(&format!("refine x{BATCH} top-{k} (streamed, LRU-bounded)"), 15, || {
        let _ = streamed_backend.refine_top_k_batch(&streamed, &qrefs, &poolrefs, k);
    });
    let src = streamed.source_stats().unwrap();
    println!(
        "{:>58}  -> {:.2}x of resident, {} rows streamed, peak {} KiB resident",
        "",
        t_str / t_res.max(1e-12),
        src.rows_streamed,
        src.peak_row_bytes / 1024
    );
    benchlib::emit_bench(
        "streamed_vs_resident",
        &[
            ("batch", BATCH as f64),
            ("m", m as f64),
            ("k", k as f64),
            ("n", ds.n as f64),
            ("shards", shards as f64),
            ("budget_mb", budget_mb as f64),
            ("resident_secs", t_res),
            ("streamed_secs", t_str),
            ("slowdown", t_str / t_res.max(1e-12)),
            ("rows_streamed", src.rows_streamed as f64),
            ("peak_row_bytes", src.peak_row_bytes as f64),
            ("evictions", src.evictions as f64),
        ],
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Section 0e: the quantised screen/refine tier vs the pure-f32 kernel, and
/// the SIMD lanes vs the scalar accumulators (no runtime required). Both
/// comparisons assert byte-identical ids before any timing is trusted: the
/// quant tier rescores every survivor through the exact f32 refine, and the
/// AVX2 f32 accumulator carries no FMA so it is bit-identical to scalar.
fn bench_quant_simd(ds: &golddiff::Dataset) {
    use golddiff::index::kernel::simd;

    const BATCH: usize = 8;
    // precision budget: small m keeps the ub-threshold tight so the int8
    // lower bound actually rejects rows instead of rescoring everything
    let m = (ds.n / 20).max(1);
    let k = (m / 2).max(1);
    let mut rng = golddiff::util::rng::Pcg64::new(67);
    let queries_data: Vec<Vec<f32>> = (0..BATCH)
        .map(|_| {
            let row = ds.proxy_row(rng.below(ds.n)).to_vec();
            row.iter().map(|&v| v + rng.normal() * 0.3).collect()
        })
        .collect();
    let queries: Vec<ProxyQuery> = queries_data
        .iter()
        .map(|q| ProxyQuery {
            proxy: q,
            class: None,
        })
        .collect();
    let full_queries: Vec<Vec<f32>> = (0..BATCH as u64)
        .map(|i| {
            let mut r = golddiff::util::rng::Pcg64::new(700 + i);
            let row = ds.row(r.below(ds.n)).to_vec();
            row.iter().map(|&v| v + r.normal() * 0.2).collect()
        })
        .collect();
    let qrefs: Vec<&[f32]> = full_queries.iter().map(|q| q.as_slice()).collect();

    println!("-- quantised tier vs f32 kernel (batch={BATCH}, m={m}, k={k}) --");
    let f32_backend = BatchedScan::default();
    let quant_backend = BatchedScan::default().with_quant(true);
    // exact-rescore contract: the quant screen must return byte-identical
    // ids — every survivor is re-ranked on the f32 rows before emission
    let want = f32_backend.top_m_batch(ds, &queries, m);
    assert_eq!(
        quant_backend.top_m_batch(ds, &queries, m),
        want,
        "quant screen must match the f32 kernel byte-for-byte"
    );
    let poolrefs: Vec<&[u32]> = want.iter().map(|p| p.as_slice()).collect();
    assert_eq!(
        quant_backend.refine_top_k_batch(ds, &qrefs, &poolrefs, k),
        f32_backend.refine_top_k_batch(ds, &qrefs, &poolrefs, k),
        "quant-prefiltered refine must match the f32 ladder byte-for-byte"
    );
    let t_f32 = bench(&format!("screen x{BATCH} top-{m} (f32 kernel)"), 15, || {
        let _ = f32_backend.top_m_batch(ds, &queries, m);
    });
    let t_quant = bench(&format!("screen x{BATCH} top-{m} (int8 + f32 rescore)"), 15, || {
        let _ = quant_backend.top_m_batch(ds, &queries, m);
    });
    // per-call telemetry: reset, run once, snapshot (the timed loop above
    // accumulates the counters across every iteration)
    quant_backend.reset_stats();
    let _ = quant_backend.top_m_batch(ds, &queries, m);
    let _ = quant_backend.refine_top_k_batch(ds, &qrefs, &poolrefs, k);
    let qsnap = quant_backend.stats();
    assert!(
        qsnap.quant_rows_screened > 0,
        "the quant backend must route the screen through the int8 tier"
    );
    assert_eq!(
        qsnap.quant_rows_screened,
        qsnap.bound_rejects + qsnap.rescore_rows,
        "every screened row is either bound-rejected or exactly rescored"
    );
    let reject_frac = qsnap.bound_rejects as f64 / qsnap.quant_rows_screened.max(1) as f64;
    let quant_speedup = t_f32 / t_quant.max(1e-12);
    println!(
        "{:>58}  -> quant speedup {quant_speedup:.2}x, {:.0}% rows bound-rejected, {} rescored",
        "",
        reject_frac * 100.0,
        qsnap.rescore_rows
    );
    benchlib::emit_bench(
        "quant_screen_vs_f32",
        &[
            ("batch", BATCH as f64),
            ("m", m as f64),
            ("k", k as f64),
            ("n", ds.n as f64),
            ("f32_secs", t_f32),
            ("quant_secs", t_quant),
            ("speedup", quant_speedup),
            ("quant_rows_screened", qsnap.quant_rows_screened as f64),
            ("bound_rejects", qsnap.bound_rejects as f64),
            ("rescore_rows", qsnap.rescore_rows as f64),
            ("reject_frac", reject_frac),
        ],
    );

    // SIMD lanes vs the scalar accumulators: same kernel, same tile walk,
    // only the inner accumulator differs. The f32 AVX2 path carries no FMA
    // and the i8 path widens through exact integer conversion, so both are
    // bit-identical to scalar — asserted on ids before timing.
    println!(
        "-- simd vs scalar accumulators (avx2 available: {}) --",
        simd::available()
    );
    simd::set_enabled(false);
    let want_scalar = f32_backend.top_m_batch(ds, &queries, m);
    let want_scalar_q = quant_backend.top_m_batch(ds, &queries, m);
    simd::set_enabled(true);
    assert_eq!(
        f32_backend.top_m_batch(ds, &queries, m),
        want_scalar,
        "simd f32 accumulators must be bit-identical to scalar"
    );
    assert_eq!(
        quant_backend.top_m_batch(ds, &queries, m),
        want_scalar_q,
        "simd i8 accumulators must be bit-identical to scalar"
    );
    let t_simd = bench(&format!("screen x{BATCH} top-{m} (simd lanes)"), 15, || {
        let _ = f32_backend.top_m_batch(ds, &queries, m);
    });
    simd::set_enabled(false);
    let t_scalar = bench(&format!("screen x{BATCH} top-{m} (scalar lanes)"), 15, || {
        let _ = f32_backend.top_m_batch(ds, &queries, m);
    });
    simd::set_enabled(true);
    let simd_speedup = t_scalar / t_simd.max(1e-12);
    println!("{:>58}  -> simd speedup {simd_speedup:.2}x over scalar", "");
    benchlib::emit_bench(
        "simd_vs_scalar",
        &[
            ("batch", BATCH as f64),
            ("m", m as f64),
            ("n", ds.n as f64),
            ("avx2_available", simd::available() as u64 as f64),
            ("simd_secs", t_simd),
            ("scalar_secs", t_scalar),
            ("speedup", simd_speedup),
        ],
    );
}

/// Section 0f: what the v5 per-section checksums cost — `store::load`
/// (which verifies every section on read) against the raw CRC-32 pass over
/// the same bytes, so the verify share of a load is priced explicitly. No
/// runtime required.
fn bench_checksum(ds: &golddiff::Dataset) {
    use golddiff::data::store;
    use golddiff::util::crc::crc32;

    let dir = std::env::temp_dir().join("golddiff_bench_checksum");
    std::fs::remove_dir_all(&dir).ok();
    let path = store::store_path(&dir, "bench-corpus");
    store::save_sharded(ds, &path, 4).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    println!(
        "-- v5 checksum overhead ({:.1} MiB store) --",
        bytes.len() as f64 / (1024.0 * 1024.0)
    );
    let t_crc = bench("raw crc32 over the store bytes", 10, || {
        let _ = std::hint::black_box(crc32(&bytes));
    });
    let t_load = bench("store::load (verifies every section)", 10, || {
        let _ = std::hint::black_box(store::load(&path).unwrap());
    });
    let gb_per_s = bytes.len() as f64 / t_crc.max(1e-12) / 1e9;
    println!(
        "{:>58}  -> {gb_per_s:.2} GB/s crc; verify ≈ {:.1}% of a full load",
        "",
        100.0 * t_crc / t_load.max(1e-12)
    );
    benchlib::emit_bench(
        "checksum_overhead",
        &[
            ("n", ds.n as f64),
            ("bytes", bytes.len() as f64),
            ("crc_secs", t_crc),
            ("crc_gb_per_s", gb_per_s),
            ("load_secs", t_load),
            ("overhead_frac", t_crc / t_load.max(1e-12)),
        ],
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Section 0g: the distributed shard-worker tier — identical screen +
/// refine work through a loopback `RemoteShardBackend` fleet vs the
/// in-process `ShardedBackend` it wraps. Byte-equality is asserted before
/// timing (the merge-associativity contract from `index/README.md`), and
/// the BENCH line carries the remote telemetry.
fn bench_distributed(ds: &golddiff::Dataset) {
    use std::sync::Arc;

    use golddiff::index::RemoteShardBackend;

    const BATCH: usize = 8;
    let shards = 8;
    let workers = 2;
    let m = (ds.n / 10).max(1);
    let k = (ds.n / 20).max(1);
    let opts = BackendOpts {
        shards,
        ..BackendOpts::default()
    };
    let local = ShardedBackend::build(ds, RetrievalBackendKind::Batched, opts);
    let remote = RemoteShardBackend::loopback(
        Arc::new(ds.clone()),
        RetrievalBackendKind::Batched,
        opts,
        workers,
        true,
        30_000,
    )
    .unwrap();

    let mut rng = golddiff::util::rng::Pcg64::new(83);
    let queries_data: Vec<Vec<f32>> = (0..BATCH)
        .map(|_| {
            let row = ds.proxy_row(rng.below(ds.n)).to_vec();
            row.iter().map(|&v| v + rng.normal() * 0.3).collect()
        })
        .collect();
    let queries: Vec<ProxyQuery> = queries_data
        .iter()
        .map(|q| ProxyQuery {
            proxy: q,
            class: None,
        })
        .collect();
    let full_queries: Vec<Vec<f32>> = (0..BATCH as u64)
        .map(|i| {
            let mut r = golddiff::util::rng::Pcg64::new(800 + i);
            (0..ds.d).map(|_| r.normal()).collect()
        })
        .collect();

    println!("-- distributed loopback fleet vs in-process (shards={shards}, workers={workers}) --");
    let pools = local.top_m_batch(ds, &queries, m);
    assert_eq!(
        remote.top_m_batch(ds, &queries, m),
        pools,
        "remote coarse screen must equal in-process byte-for-byte"
    );
    let qrefs: Vec<&[f32]> = full_queries.iter().map(|q| q.as_slice()).collect();
    let poolrefs: Vec<&[u32]> = pools.iter().map(|p| p.as_slice()).collect();
    assert_eq!(
        remote.refine_top_k_batch(ds, &qrefs, &poolrefs, k),
        local.refine_top_k_batch(ds, &qrefs, &poolrefs, k),
        "remote refine must equal in-process byte-for-byte"
    );
    let t_local = bench(&format!("screen+refine x{BATCH} (in-process)"), 15, || {
        let pools = local.top_m_batch(ds, &queries, m);
        let poolrefs: Vec<&[u32]> = pools.iter().map(|p| p.as_slice()).collect();
        let _ = local.refine_top_k_batch(ds, &qrefs, &poolrefs, k);
    });
    let t_remote = bench(&format!("screen+refine x{BATCH} (loopback workers)"), 15, || {
        let pools = remote.top_m_batch(ds, &queries, m);
        let poolrefs: Vec<&[u32]> = pools.iter().map(|p| p.as_slice()).collect();
        let _ = remote.refine_top_k_batch(ds, &qrefs, &poolrefs, k);
    });
    let snap = remote.stats();
    assert!(snap.remote_ops > 0, "the fleet must actually answer ops");
    assert_eq!(snap.workers_lost, 0, "no worker may be lost in a clean run");
    println!(
        "{:>58}  -> {:.2}x of in-process, {} remote ops, {} retries",
        "",
        t_remote / t_local.max(1e-12),
        snap.remote_ops,
        snap.remote_retries
    );
    benchlib::emit_bench(
        "distributed_vs_inprocess",
        &[
            ("batch", BATCH as f64),
            ("m", m as f64),
            ("k", k as f64),
            ("n", ds.n as f64),
            ("shards", shards as f64),
            ("workers", workers as f64),
            ("inprocess_secs", t_local),
            ("remote_secs", t_remote),
            ("overhead", t_remote / t_local.max(1e-12)),
            ("remote_ops", snap.remote_ops as f64),
            ("remote_retries", snap.remote_retries as f64),
            ("workers_lost", snap.workers_lost as f64),
        ],
    );
}

fn main() -> anyhow::Result<()> {
    // GOLDDIFF_BENCH_N shrinks the corpus for CI smoke runs (synthesised
    // directly, bypassing the on-disk store so sizes never conflict)
    let ds = match std::env::var("GOLDDIFF_BENCH_N")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
    {
        Some(n) => {
            let mut spec = golddiff::data::synthetic::preset("cifar-sim")
                .expect("preset")
                .clone();
            spec.n = n;
            golddiff::Dataset::synthesize(&spec, 0)
        }
        None => benchlib::dataset("cifar-sim", 0)?,
    };
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let mut rng = golddiff::util::rng::Pcg64::new(1);
    let x_t: Vec<f32> = (0..ds.d).map(|_| rng.normal()).collect();
    let q: Vec<f32> = x_t.iter().map(|v| v / sched.alpha_bar(5).sqrt()).collect();
    let qp = golddiff::data::synthetic::proxy_embed(&q, ds.h, ds.w, ds.c);

    println!("== perf_hotpath (cifar-sim, N={}, D={}) ==", ds.n, ds.d);

    // 0. pluggable retrieval backends (no runtime required)
    bench_retrieval_backends(&ds);

    // 0b. concentration warm-start vs cold screening (no runtime required)
    bench_warm_start(&ds, &sched);

    // 0c. shard-parallel retrieval vs the monolithic scan (no runtime
    // required; pins the exact-merge contract before timing)
    bench_sharded(&ds);

    // 0d. out-of-core corpus: streamed (LRU-bounded) vs resident serving
    // (no runtime required; byte-equality asserted before timing)
    bench_streamed(&ds);

    // 0e. quantised screen/refine tier vs f32, and simd vs scalar lanes
    // (no runtime required; byte-equality asserted before timing)
    bench_quant_simd(&ds);

    // 0f. v5 per-section checksum verification overhead (no runtime
    // required)
    bench_checksum(&ds);

    // 0g. distributed shard-worker tier: loopback fleet vs in-process
    // (no runtime required; byte-equality asserted before timing)
    bench_distributed(&ds);

    // 0h. Gaussian closed-form tick vs the retrieval tick it replaces
    // (no runtime required; retrieval-segment byte-equality asserted
    // before timing)
    bench_gauss(&ds, &sched);

    // 0i. few-step sampling: budgeted heun with subset-reuse correctors vs
    // the full-grid ddim path (no runtime required; screen-count and
    // matched-quality contracts asserted before the BENCH line)
    bench_fewstep(&ds, &sched);

    // 1. coarse scan vs threads
    for threads in [1usize, 2, 4, 8] {
        let idx = ProxyIndex { threads };
        let m = ds.n / 4;
        let secs = bench(&format!("coarse scan top-{m} ({threads} threads)"), 20, || {
            let _ = idx.top_m(&ds, &qp, m);
        });
        println!("{:>58}  -> {:.1} Mrows/s", "", ds.n as f64 / secs / 1e6);
    }

    // 2. exact refine
    let idx = ProxyIndex::default();
    let cands = idx.top_m(&ds, &qp, ds.n / 4);
    bench("exact refine top-k (m=N/4 -> k=N/20)", 20, || {
        let _ = idx.refine_top_k(&ds, &q, &cands, ds.n / 20);
    });

    // 3.-6. need compiled artifacts; CI smoke runs stop here
    let rt = match benchlib::runtime() {
        Ok(rt) => rt,
        Err(e) => {
            println!("-- skipping XLA sections (runtime unavailable: {e:#}) --");
            return Ok(());
        }
    };

    // 3. gather + upload per bucket
    let golden = idx.refine_top_k(&ds, &q, &cands, 512);
    for bucket in [512usize, 2048] {
        let mut buf = Vec::new();
        let mut mask = Vec::new();
        bench(&format!("gather+upload bucket {bucket}"), 20, || {
            ds.gather_rows(&golden, bucket, &mut buf, &mut mask);
            let _c = rt.upload(&buf, &[bucket, ds.d]).unwrap();
            let _m = rt.upload(&mask, &[bucket]).unwrap();
        });
    }

    // 4./5. dispatch per bucket: pallas vs jnp twin
    let alphas = rt.upload(&[sched.alpha_bar(5), sched.alpha_prev(5)], &[2])?;
    let bx = rt.upload(&x_t, &[ds.d])?;
    for bucket in [512usize, 2048] {
        let mut buf = Vec::new();
        let mut mask = Vec::new();
        ds.gather_rows(&golden, bucket, &mut buf, &mut mask);
        let bc = rt.upload(&buf, &[bucket, ds.d])?;
        let bm = rt.upload(&mask, &[bucket])?;
        bench(&format!("golden_step (fused XLA, serving) k={bucket}"), 30, || {
            let _ = rt
                .run_step(
                    &format!("golden_step__cifar-sim__k{bucket}"),
                    &[&bx, &bc, &bm, &alphas],
                )
                .unwrap();
        });
        bench(&format!("golden_step_pallas (interpret L1) k={bucket}"), 30, || {
            let _ = rt
                .run_step(
                    &format!("golden_step_pallas__cifar-sim__k{bucket}"),
                    &[&bx, &bc, &bm, &alphas],
                )
                .unwrap();
        });
    }

    // 6. full XLA-backed step per method
    use golddiff::coordinator::xla_denoiser::XlaDenoiser;
    use golddiff::denoiser::DenoiserKind;
    for kind in [
        DenoiserKind::GoldDiff,
        DenoiserKind::GoldDiffPca,
        DenoiserKind::Optimal,
        DenoiserKind::Pca,
    ] {
        let mut den = XlaDenoiser::new(std::rc::Rc::clone(&rt), &ds, kind)?;
        for step in [0usize, 9] {
            let ctx = StepContext {
                ds: &ds,
                sched: &sched,
                step,
                class: None,
            };
            bench(&format!("e2e step {} t={step}", kind.name()), 10, || {
                let _ = den.step(&x_t, &ctx).unwrap();
            });
            println!(
                "{:>58}  -> scan {:.2} ms, dispatch {:.2} ms",
                "",
                den.telemetry.scan_secs * 1e3,
                den.telemetry.dispatch_secs * 1e3
            );
        }
    }

    // 6b. grouped GoldDiff steps: one batched retrieval per tick group
    {
        let backend: std::sync::Arc<dyn RetrievalBackend> =
            std::sync::Arc::new(BatchedScan::default());
        let mut den = XlaDenoiser::new(std::rc::Rc::clone(&rt), &ds, DenoiserKind::GoldDiff)?
            .with_retrieval(backend);
        let xs_data: Vec<Vec<f32>> = (0..8u64)
            .map(|i| {
                let mut r = golddiff::util::rng::Pcg64::new(50 + i);
                (0..ds.d).map(|_| r.normal()).collect()
            })
            .collect();
        for step in [0usize, 9] {
            let ctx = StepContext {
                ds: &ds,
                sched: &sched,
                step,
                class: None,
            };
            let xs: Vec<&[f32]> = xs_data.iter().map(|x| x.as_slice()).collect();
            let ctxs: Vec<&StepContext> = xs.iter().map(|_| &ctx).collect();
            let secs = bench(&format!("e2e grouped x8 golddiff t={step}"), 10, || {
                let _ = den.step_group(&xs, &ctxs).unwrap();
            });
            benchlib::emit_bench(
                "e2e_grouped_step",
                &[
                    ("batch", 8.0),
                    ("step", step as f64),
                    ("secs_per_group", secs),
                    ("secs_per_seq", secs / 8.0),
                ],
            );
        }
    }
    Ok(())
}
