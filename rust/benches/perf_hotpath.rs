//! §Perf microbenchmarks for the serving hot path (EXPERIMENTS.md §Perf):
//!
//!   1. coarse proxy scan throughput (rows/s) vs thread count;
//!   2. exact refine top-k inside the candidate pool;
//!   3. gather + upload of the golden subset;
//!   4. PJRT dispatch of golden_step per k-bucket (Pallas streaming kernel);
//!   5. golden_step (Pallas) vs golden_step_jnp (pure-XLA twin) — the
//!      L1-vs-L2 structural comparison;
//!   6. end-to-end XLA-backed step breakdown per method.

use std::time::Instant;

use golddiff::benchlib;
use golddiff::denoiser::StepContext;
use golddiff::index::scan::ProxyIndex;
use golddiff::schedule::noise::{NoiseSchedule, ScheduleKind};
use golddiff::util::timer::TimingStats;

fn bench<F: FnMut()>(label: &str, iters: usize, mut f: F) -> f64 {
    f(); // warmup (compiles executables on first use)
    let mut t = TimingStats::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        t.record(t0.elapsed());
    }
    println!(
        "{label:58} {:>10.3} ms  (min {:.3} ms, n={iters})",
        t.mean() * 1e3,
        t.min() * 1e3
    );
    t.mean()
}

fn main() -> anyhow::Result<()> {
    let ds = benchlib::dataset("cifar-sim", 0)?;
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let rt = benchlib::runtime()?;
    let mut rng = golddiff::util::rng::Pcg64::new(1);
    let x_t: Vec<f32> = (0..ds.d).map(|_| rng.normal()).collect();
    let q: Vec<f32> = x_t.iter().map(|v| v / sched.alpha_bar(5).sqrt()).collect();
    let qp = golddiff::data::synthetic::proxy_embed(&q, ds.h, ds.w, ds.c);

    println!("== perf_hotpath (cifar-sim, N={}, D={}) ==", ds.n, ds.d);

    // 1. coarse scan vs threads
    for threads in [1usize, 2, 4, 8] {
        let idx = ProxyIndex { threads };
        let m = ds.n / 4;
        let secs = bench(&format!("coarse scan top-{m} ({threads} threads)"), 20, || {
            let _ = idx.top_m(&ds, &qp, m);
        });
        println!("{:>58}  -> {:.1} Mrows/s", "", ds.n as f64 / secs / 1e6);
    }

    // 2. exact refine
    let idx = ProxyIndex::default();
    let cands = idx.top_m(&ds, &qp, ds.n / 4);
    bench("exact refine top-k (m=N/4 -> k=N/20)", 20, || {
        let _ = idx.refine_top_k(&ds, &q, &cands, ds.n / 20);
    });

    // 3. gather + upload per bucket
    let golden = idx.refine_top_k(&ds, &q, &cands, 512);
    for bucket in [512usize, 2048] {
        let mut buf = Vec::new();
        let mut mask = Vec::new();
        bench(&format!("gather+upload bucket {bucket}"), 20, || {
            ds.gather_rows(&golden, bucket, &mut buf, &mut mask);
            let _c = rt.upload(&buf, &[bucket, ds.d]).unwrap();
            let _m = rt.upload(&mask, &[bucket]).unwrap();
        });
    }

    // 4./5. dispatch per bucket: pallas vs jnp twin
    let alphas = rt.upload(&[sched.alpha_bar(5), sched.alpha_prev(5)], &[2])?;
    let bx = rt.upload(&x_t, &[ds.d])?;
    for bucket in [512usize, 2048] {
        let mut buf = Vec::new();
        let mut mask = Vec::new();
        ds.gather_rows(&golden, bucket, &mut buf, &mut mask);
        let bc = rt.upload(&buf, &[bucket, ds.d])?;
        let bm = rt.upload(&mask, &[bucket])?;
        bench(&format!("golden_step (fused XLA, serving) k={bucket}"), 30, || {
            let _ = rt
                .run_step(
                    &format!("golden_step__cifar-sim__k{bucket}"),
                    &[&bx, &bc, &bm, &alphas],
                )
                .unwrap();
        });
        bench(&format!("golden_step_pallas (interpret L1) k={bucket}"), 30, || {
            let _ = rt
                .run_step(
                    &format!("golden_step_pallas__cifar-sim__k{bucket}"),
                    &[&bx, &bc, &bm, &alphas],
                )
                .unwrap();
        });
    }

    // 6. full XLA-backed step per method
    use golddiff::coordinator::xla_denoiser::XlaDenoiser;
    use golddiff::denoiser::DenoiserKind;
    for kind in [
        DenoiserKind::GoldDiff,
        DenoiserKind::GoldDiffPca,
        DenoiserKind::Optimal,
        DenoiserKind::Pca,
    ] {
        let mut den = XlaDenoiser::new(std::rc::Rc::clone(&rt), &ds, kind)?;
        for step in [0usize, 9] {
            let ctx = StepContext {
                ds: &ds,
                sched: &sched,
                step,
                class: None,
            };
            bench(&format!("e2e step {} t={step}", kind.name()), 10, || {
                let _ = den.step(&x_t, &ctx).unwrap();
            });
            println!(
                "{:>58}  -> scan {:.2} ms, dispatch {:.2} ms",
                "",
                den.telemetry.scan_secs * 1e3,
                den.telemetry.dispatch_secs * 1e3
            );
        }
    }
    Ok(())
}
