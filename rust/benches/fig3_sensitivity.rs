//! Regenerates the paper's Fig. 3: (a) weight-distribution evolution on the
//! CIFAR-10 stand-in, (b) sensitivity to random subset size
//! N_sub ∈ {10, 100, 1000, 5000} vs the full dataset, split by stage.
fn main() -> anyhow::Result<()> {
    golddiff::benchlib::figures::run_concentration("cifar-sim", 4, 0)?;
    golddiff::benchlib::figures::run_sensitivity("cifar-sim", 0)?;
    Ok(())
}
