//! Regenerates the paper's Fig. 1: Posterior Progressive Concentration on
//! the Moons dataset — effective support / 90%-mass support / top-1 weight
//! per denoising step.
fn main() -> anyhow::Result<()> {
    golddiff::benchlib::figures::run_concentration("moons", 8, 0)?;
    Ok(())
}
