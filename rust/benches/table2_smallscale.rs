//! Regenerates the paper's Table 2: efficacy (MSE, r² vs oracle) and
//! efficiency (time/step, memory) on the CIFAR-10 / CelebA-HQ / AFHQ
//! stand-ins for Optimal / Wiener / Kamb / PCA / GoldDiff.
fn main() -> anyhow::Result<()> {
    golddiff::benchlib::experiments::run_table2(0)?;
    Ok(())
}
