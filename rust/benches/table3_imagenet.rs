//! Regenerates the paper's Table 3: ImageNet-1K (sim, N=50k, 1000 classes)
//! unconditional + conditional generation at T ∈ {10, 100} for PCA,
//! PCA (Unbiased) and GoldDiff — the paper's headline scaling result.
fn main() -> anyhow::Result<()> {
    golddiff::benchlib::experiments::run_table3(0)?;
    Ok(())
}
