//! Regenerates the paper's Fig. 6: sensitivity of GoldDiff to the coarse
//! candidate bound m_max and the golden-subset bound k_min across datasets.
fn main() -> anyhow::Result<()> {
    golddiff::benchlib::experiments::run_fig6(0)?;
    Ok(())
}
