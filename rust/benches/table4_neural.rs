//! Regenerates the paper's Table 4: validation against diverse neural
//! denoisers — the oracle under EDM-VP and EDM-VE parameterisations.
fn main() -> anyhow::Result<()> {
    golddiff::benchlib::experiments::run_table4(0)?;
    Ok(())
}
