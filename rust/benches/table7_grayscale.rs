//! Regenerates the paper's Table 7 (App. B): MNIST / Fashion-MNIST
//! stand-ins, full method roster.
fn main() -> anyhow::Result<()> {
    golddiff::benchlib::experiments::run_table7(0)?;
    Ok(())
}
