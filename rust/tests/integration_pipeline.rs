//! Cross-module integration: dataset synthesis → store roundtrip → coarse
//! index → denoisers → sampler → oracle scoring, plus XLA-vs-CPU
//! cross-validation on an image preset.

use std::sync::Arc;

use golddiff::data::store;
use golddiff::data::synthetic::preset;
use golddiff::denoiser::golddiff::{BaseWeighting, GoldDiff};
use golddiff::denoiser::{Denoiser, DenoiserKind, StepContext};
use golddiff::index::backend::{BackendOpts, RetrievalBackend, RetrievalBackendKind};
use golddiff::index::RemoteShardBackend;
use golddiff::metrics::EfficacyAccum;
use golddiff::oracle::GmmOracle;
use golddiff::sampler;
use golddiff::schedule::noise::{NoiseSchedule, ScheduleKind};
use golddiff::Dataset;

fn small(name: &str, n: usize, seed: u64) -> Dataset {
    let mut spec = preset(name).unwrap().clone();
    spec.n = n;
    Dataset::synthesize(&spec, seed)
}

#[test]
fn full_pipeline_moons_store_roundtrip_then_sample() {
    let dir = std::env::temp_dir().join("golddiff_it_pipeline");
    std::fs::remove_dir_all(&dir).ok();
    let ds = small("moons", 600, 3);
    store::save(&ds, &store::store_path(&dir, "moons")).unwrap();
    let ds = store::load(&store::store_path(&dir, "moons")).unwrap();
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);

    // every method produces a finite on-manifold-ish sample
    for kind in [
        DenoiserKind::Optimal,
        DenoiserKind::GoldDiff,
    ] {
        let mut den = kind.build(&ds, &sched);
        let traj = sampler::sample(den.as_mut(), &ds, &sched, 1, sampler::SamplerOpts::default());
        let x = traj.final_sample();
        assert!(x.iter().all(|v| v.is_finite()), "{kind:?}");
        let nearest: f32 = (0..ds.n)
            .map(|i| {
                ds.row(i)
                    .iter()
                    .zip(x)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f32>()
            })
            .fold(f32::INFINITY, f32::min);
        assert!(nearest < 0.5, "{kind:?} sample far from manifold: {nearest}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn golddiff_beats_or_matches_pca_and_runs_faster_cpu_path() {
    // The paper's core quantitative claim on the CPU reference path.
    let ds = small("cifar-sim", 1500, 5);
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let oracle = GmmOracle::new(ds.gmm.clone());

    let score = |kind: DenoiserKind| -> (f64, f64) {
        let mut den = kind.build(&ds, &sched);
        let mut acc = EfficacyAccum::new();
        let mut secs = 0.0;
        for s in 0..3u64 {
            let mut rng = golddiff::util::rng::Pcg64::new(s);
            let mut x = sampler::init_noise(ds.d, &mut rng);
            for step in 0..sched.steps {
                let target = oracle.denoise(&x, sched.alpha_bar(step));
                let ctx = StepContext {
                    ds: &ds,
                    sched: &sched,
                    step,
                    class: None,
                };
                let t0 = std::time::Instant::now();
                let out = den.denoise(&x, &ctx);
                secs += t0.elapsed().as_secs_f64();
                acc.update(&out.f_hat, &target);
                x = sampler::ddim_update(
                    &x,
                    &target,
                    sched.alpha_bar(step),
                    sched.alpha_prev(step),
                    0.0,
                    &mut rng,
                );
            }
        }
        (acc.mse(), secs)
    };

    let (mse_pca, t_pca) = score(DenoiserKind::Pca);
    let (mse_gold, t_gold) = score(DenoiserKind::GoldDiffPca);
    assert!(
        mse_gold <= mse_pca * 1.10,
        "GoldDiff mse {mse_gold} should match/beat PCA {mse_pca}"
    );
    assert!(
        t_gold < t_pca,
        "GoldDiff ({t_gold:.3}s) must be faster than full-scan PCA ({t_pca:.3}s)"
    );
}

#[test]
fn xla_and_cpu_paths_agree_on_image_preset() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        return;
    }
    // mnist-sim at its full preset size so manifest buckets match; shares
    // the `data/` cache with `make data` so repeat runs just load the store
    let dir = golddiff::benchlib::data_dir();
    let ds = store::load_or_synthesize(&dir, "mnist-sim", 0).unwrap();
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let rt = std::rc::Rc::new(
        golddiff::runtime::Runtime::new(std::path::Path::new("artifacts")).unwrap(),
    );

    let mut rng = golddiff::util::rng::Pcg64::new(9);
    let x_t: Vec<f32> = (0..ds.d).map(|_| rng.normal()).collect();

    use golddiff::coordinator::xla_denoiser::XlaDenoiser;
    for (kind, tol) in [
        (DenoiserKind::Optimal, 1e-3f32),
        (DenoiserKind::Wiener, 1e-3),
        (DenoiserKind::GoldDiff, 1e-3),
    ] {
        let mut xla = XlaDenoiser::new(std::rc::Rc::clone(&rt), &ds, kind).unwrap();
        let mut cpu = kind.build(&ds, &sched);
        for step in [2usize, 8] {
            let ctx = StepContext {
                ds: &ds,
                sched: &sched,
                step,
                class: None,
            };
            let fx = xla.denoise(&x_t, &ctx).f_hat;
            let fc = cpu.denoise(&x_t, &ctx).f_hat;
            let max_err = fx
                .iter()
                .zip(&fc)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(max_err < tol, "{kind:?} step {step}: max err {max_err}");
        }
    }
}

#[test]
fn determinism_matrix_backend_kernel_warmstart() {
    // Satellite: one seeded synthetic dataset stepped through the full
    // retrieval matrix — backend ∈ {flat, batched, cluster} × kernel ∈
    // {on, off} × warm_start ∈ {on, off} × shards ∈ {1, 2, 7} ×
    // resident ∈ {true, false} × quant ∈ {on, off} × simd ∈ {on, off} —
    // must produce byte-identical golden subsets for a tick group at every
    // sampling point, and byte-identical samples for a full
    // single-sequence trajectory. This is the engine's exactness contract:
    // every knob — the corpus shard count (per-shard heaps merge with a
    // deterministic (distance, row id) tie-break), corpus residency (a
    // streamed corpus serves the exact bytes the resident one holds), the
    // int8 screen tier (sound bounds + exact f32 rescore), and the SIMD
    // lanes (no FMA in the f32 accumulator, exact integer widening in the
    // i8 one) included — is a performance/residency lever, never a result
    // lever. The quant/simd axes vary on a representative slice (kernel
    // on, warm on, shards=2) so the matrix stays bounded; every other
    // cell runs the default (quant off, simd on).
    let ds = small("mnist-sim", 260, 11);
    let dir = std::env::temp_dir().join("golddiff_it_matrix_streamed");
    std::fs::remove_dir_all(&dir).ok();
    let path = store::store_path(&dir, "mnist-sim");
    store::save_sharded(&ds, &path, 4).unwrap();
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let xs_data: Vec<Vec<f32>> = (0..6)
        .map(|i| {
            let mut rng = golddiff::util::rng::Pcg64::new(700 + i);
            (0..ds.d).map(|_| rng.normal()).collect()
        })
        .collect();

    let mut reference: Option<(Vec<Vec<Vec<u32>>>, Vec<f32>)> = None;
    for resident in [true, false] {
        for &backend in RetrievalBackendKind::all() {
            for kernel in [true, false] {
                for warm in [true, false] {
                    for (shards, quant, simd) in [
                        (1usize, false, true),
                        (2, false, true),
                        (7, false, true),
                        (2, true, true),
                        (2, true, false),
                        (2, false, false),
                    ] {
                        // the non-default quant/simd cells (the last three)
                        // run on a representative shards=2 slice with the
                        // kernel and the warm screen on; the default cells
                        // run everywhere
                        if (quant || !simd) && !(kernel && warm) {
                            continue;
                        }
                        // the streamed arm re-opens the store data-free per
                        // combo (sources are stateful LRUs; a fresh one pins
                        // cold-start determinism too)
                        let ds_run = if resident {
                            None
                        } else {
                            Some(store::open_streaming(&path, shards, 0).unwrap())
                        };
                        let ds_run: &Dataset = ds_run.as_ref().unwrap_or(&ds);
                        let opts = BackendOpts {
                            threads: 2,
                            clusters: 8,
                            kernel,
                            shards,
                            quant,
                            simd,
                            ..BackendOpts::default()
                        };
                        let build = || {
                            GoldDiff::paper_defaults(ds_run, &sched, BaseWeighting::Golden)
                                .with_backend(backend.build(ds_run, opts))
                                .with_warm_start(warm)
                        };
                        // (a) a 6-sequence tick group stepped 0..steps — the
                        // warm screen sees the previous step's subsets, as in
                        // serving
                        let mut gd = build();
                        let mut subsets = Vec::new();
                        for step in 0..sched.steps {
                            let ctx = StepContext {
                                ds: ds_run,
                                sched: &sched,
                                step,
                                class: None,
                            };
                            let xs: Vec<&[f32]> =
                                xs_data.iter().map(|x| x.as_slice()).collect();
                            let ctxs: Vec<&StepContext> = xs.iter().map(|_| &ctx).collect();
                            subsets.push(gd.golden_subsets(&xs, &ctxs));
                        }
                        // (b) a full single-sequence reverse trajectory
                        let mut den = build();
                        let traj = sampler::sample(
                            &mut den as &mut dyn Denoiser,
                            ds_run,
                            &sched,
                            5,
                            sampler::SamplerOpts::default(),
                        );
                        let sample = traj.final_sample().to_vec();
                        let label = format!(
                            "{}/kernel={kernel}/warm={warm}/shards={shards}/resident={resident}/quant={quant}/simd={simd}",
                            backend.name()
                        );
                        match &reference {
                            None => reference = Some((subsets, sample)),
                            Some((ref_subsets, ref_sample)) => {
                                assert_eq!(
                                    ref_subsets, &subsets,
                                    "{label}: golden subsets diverged"
                                );
                                assert_eq!(ref_sample, &sample, "{label}: samples diverged");
                            }
                        }
                    }
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn determinism_matrix_gauss_axis_leaves_retrieval_segment_byte_identical() {
    // PR-9 satellite: the determinism matrix gains a `gauss` axis. With a
    // forced switch point the high-noise prefix is served closed-form
    // (support 0 — zero coarse screens, zero refines), and every tick at
    // or beyond the switch must stay byte-identical to the gauss-off
    // cell: the fast path is a prefix substitution, never a result lever
    // inside the retrieval segment. Teacher-forced inputs (the same x_t
    // fed to both cells at every step) isolate that per-tick contract
    // from the trajectory divergence the approximate prefix legitimately
    // introduces. The warm axis rides along because the two cells reach
    // the first retrieval tick with different warm histories (gauss-off
    // has step-2 seeds, gauss-on starts cold) — exactness means the
    // history difference must not show in the output.
    use golddiff::denoiser::gaussian::gauss_result;
    let ds = small("mnist-sim", 260, 13);
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let gm = ds
        .gauss_moments()
        .expect("resident datasets build the moment tier lazily");
    let xs_data: Vec<Vec<f32>> = (0..sched.steps)
        .map(|i| {
            let mut rng = golddiff::util::rng::Pcg64::new(1300 + i as u64);
            (0..ds.d).map(|_| rng.normal()).collect()
        })
        .collect();
    const SWITCH: usize = 3;
    for &backend in RetrievalBackendKind::all() {
        for warm in [true, false] {
            let opts = BackendOpts {
                threads: 2,
                clusters: 8,
                ..BackendOpts::default()
            };
            let build = |switch: usize| {
                GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden)
                    .with_backend(backend.build(&ds, opts))
                    .with_warm_start(warm)
                    .with_gauss(switch)
            };
            let mut off = build(0);
            let mut on = build(SWITCH);
            for step in 0..sched.steps {
                let ctx = StepContext {
                    ds: &ds,
                    sched: &sched,
                    step,
                    class: None,
                };
                let x = &xs_data[step];
                let a = off.denoise(x, &ctx);
                let b = on.denoise(x, &ctx);
                let label = format!("{}/warm={warm}/step={step}", backend.name());
                if step < SWITCH {
                    assert_eq!(b.support, 0, "{label}: gauss tick must screen nothing");
                    let closed = gauss_result(gm, x, ctx.alpha_bar(), ctx.class);
                    assert_eq!(b.f_hat, closed.f_hat, "{label}: not the closed form");
                } else {
                    assert_eq!(a.f_hat, b.f_hat, "{label}: retrieval segment diverged");
                    assert_eq!(a.support, b.support, "{label}: support diverged");
                }
            }
            assert_eq!(on.gauss_ticks, SWITCH as u64);
            assert_eq!(off.gauss_ticks, 0);
        }
    }
}

#[test]
fn determinism_matrix_solver_axis_is_deterministic_and_ddim_is_legacy() {
    // PR-10 satellite: the determinism matrix gains a solver axis. Each
    // solver must be a deterministic function of the seed — byte-identical
    // across backends × warm-screen settings, because the subset-reuse
    // corrector rides the same exactness contract as the warm screen —
    // while the ddim cell must stay byte-identical to the legacy default
    // sampler, and the higher-order solvers must actually move the
    // trajectory (a corrector that changed nothing would cost a refine for
    // no accuracy). A full-grid budget (0 or ≥ the segment) must collapse
    // the plan to the default path, byte for byte.
    use golddiff::schedule::steps::{churn_prior, StepPlan};
    let ds = small("mnist-sim", 260, 17);
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let opts = BackendOpts {
        threads: 2,
        clusters: 8,
        ..BackendOpts::default()
    };
    let solvers = [sampler::Solver::Ddim, sampler::Solver::Heun, sampler::Solver::Dpm2];
    let mut by_solver: Vec<Option<Vec<f32>>> = solvers.iter().map(|_| None).collect();
    for (si, &solver) in solvers.iter().enumerate() {
        for &backend in RetrievalBackendKind::all() {
            for warm in [true, false] {
                let mut den = GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden)
                    .with_backend(backend.build(&ds, opts))
                    .with_warm_start(warm);
                let t = sampler::sample(
                    &mut den as &mut dyn Denoiser,
                    &ds,
                    &sched,
                    7,
                    sampler::SamplerOpts {
                        solver,
                        ..sampler::SamplerOpts::default()
                    },
                );
                let x = t.final_sample().to_vec();
                let label = format!("{}/{}/warm={warm}", solver.name(), backend.name());
                match &by_solver[si] {
                    None => by_solver[si] = Some(x),
                    Some(r) => assert_eq!(r, &x, "{label}: solver cell diverged"),
                }
            }
        }
    }
    // the ddim cell is the legacy sampler — `SamplerOpts::default()` runs it
    let build = || {
        GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden)
            .with_backend(RetrievalBackendKind::Batched.build(&ds, opts))
            .with_warm_start(true)
    };
    let mut den = build();
    let legacy = sampler::sample(
        &mut den as &mut dyn Denoiser,
        &ds,
        &sched,
        7,
        sampler::SamplerOpts::default(),
    );
    assert_eq!(
        by_solver[0].as_deref(),
        Some(legacy.final_sample()),
        "ddim must be byte-identical to the legacy default"
    );
    assert_ne!(by_solver[0], by_solver[1], "heun must move the trajectory");
    assert_ne!(by_solver[0], by_solver[2], "dpm2 must move the trajectory");
    // full-grid budgets collapse the plan to the default path
    for budget in [0usize, sched.steps, sched.steps + 5] {
        let plan = StepPlan::budgeted(&sched, budget, 0, &churn_prior(&sched));
        assert!(plan.is_full(), "budget {budget} must keep the full grid");
        let mut den = build();
        let t = sampler::sample_planned(
            &mut den as &mut dyn Denoiser,
            &ds,
            &sched,
            7,
            sampler::SamplerOpts::default(),
            &plan,
        );
        assert_eq!(
            t.final_sample(),
            legacy.final_sample(),
            "budget {budget}: full-grid plan diverged from the default"
        );
    }
}

/// One determinism-matrix cell over an arbitrary backend: the 4-sequence
/// tick-group golden subsets at every step (warm screen seeing the
/// previous step's subsets, as in serving) plus a full single-sequence
/// trajectory.
fn run_cell(
    ds_run: &Dataset,
    sched: &NoiseSchedule,
    xs_data: &[Vec<f32>],
    backend: Arc<dyn RetrievalBackend>,
) -> (Vec<Vec<Vec<u32>>>, Vec<f32>) {
    let mut gd = GoldDiff::paper_defaults(ds_run, sched, BaseWeighting::Golden)
        .with_backend(Arc::clone(&backend))
        .with_warm_start(true);
    let mut subsets = Vec::new();
    for step in 0..sched.steps {
        let ctx = StepContext {
            ds: ds_run,
            sched,
            step,
            class: None,
        };
        let xs: Vec<&[f32]> = xs_data.iter().map(|x| x.as_slice()).collect();
        let ctxs: Vec<&StepContext> = xs.iter().map(|_| &ctx).collect();
        subsets.push(gd.golden_subsets(&xs, &ctxs));
    }
    let mut den = GoldDiff::paper_defaults(ds_run, sched, BaseWeighting::Golden)
        .with_backend(backend)
        .with_warm_start(true);
    let traj = sampler::sample(
        &mut den as &mut dyn Denoiser,
        ds_run,
        sched,
        5,
        sampler::SamplerOpts::default(),
    );
    (subsets, traj.final_sample().to_vec())
}

#[test]
fn determinism_matrix_remote_axis_matches_in_process() {
    // Tentpole: the distributed loopback tier is a transport, not a result
    // lever — for shards ∈ {1, 2, 7} a worker fleet serves golden subsets
    // and full trajectories byte-identical to the in-process backend built
    // from the same options. The last cell re-runs shards=7 off a streamed
    // store with seeded transient faults at the read seam (the
    // GOLDDIFF_FAULT_SEED path): the bounded retry absorbs them without
    // changing a byte on either side of the wire.
    let base = Arc::new(small("mnist-sim", 240, 31));
    let dir = std::env::temp_dir().join("golddiff_it_matrix_remote");
    std::fs::remove_dir_all(&dir).ok();
    let path = store::store_path(&dir, "mnist-sim");
    store::save_sharded(&base, &path, 4).unwrap();
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let xs_data: Vec<Vec<f32>> = (0..4)
        .map(|i| {
            let mut rng = golddiff::util::rng::Pcg64::new(900 + i);
            (0..base.d).map(|_| rng.normal()).collect()
        })
        .collect();

    for (shards, workers, faulted) in
        [(1usize, 1usize, false), (2, 2, false), (7, 3, false), (7, 2, true)]
    {
        let opts = BackendOpts {
            threads: 2,
            kernel: true,
            shards,
            ..BackendOpts::default()
        };
        // the faulted arm streams the corpus with the first 5 reads
        // faulting (under the 6-retry budget, as in the rows-level fault
        // tests); the clean arms share the resident corpus
        let ds_run: Arc<Dataset> = if faulted {
            let fault = golddiff::util::fault::FaultInjector::transient(31, 1.0).with_limit(5);
            let st = store::open_streaming_with(&path, shards, 0, Some(Arc::new(fault)));
            Arc::new(st.unwrap())
        } else {
            Arc::clone(&base)
        };
        let local = run_cell(
            &ds_run,
            &sched,
            &xs_data,
            RetrievalBackendKind::Batched.build(&ds_run, opts),
        );
        let fleet = RemoteShardBackend::loopback(
            Arc::clone(&ds_run),
            RetrievalBackendKind::Batched,
            opts,
            workers,
            true,
            2_000,
        )
        .unwrap();
        let remote = run_cell(&ds_run, &sched, &xs_data, Arc::new(fleet));
        assert_eq!(
            local, remote,
            "shards={shards} workers={workers} faulted={faulted}: remote tier diverged"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn streamed_forced_eviction_serves_byte_identical_samples() {
    // Satellite: the out-of-core engine contract end to end on the CPU
    // path — a corpus larger than the LRU budget (cifar-sim rows are 3072
    // f32s; 300 rows ≈ 3.7 MiB blocked vs a 1 MiB budget over 6 shards)
    // serves full trajectories byte-identical to the resident engine while
    // evicting and re-streaming shards throughout, and resident bytes
    // never exceed the budget (debug-asserted inside the source, verified
    // against the peak here).
    let ds = small("cifar-sim", 300, 19);
    let dir = std::env::temp_dir().join("golddiff_it_forced_eviction");
    std::fs::remove_dir_all(&dir).ok();
    let path = store::store_path(&dir, "cifar-sim");
    store::save_sharded(&ds, &path, 6).unwrap();
    let st = store::open_streaming(&path, 6, 1).unwrap();

    let run = |ds_run: &Dataset| -> Vec<Vec<f32>> {
        let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
        let opts = BackendOpts {
            threads: 2,
            shards: 6,
            mem_budget_mb: 1,
            ..BackendOpts::default()
        };
        (0..3u64)
            .map(|seed| {
                let mut den =
                    GoldDiff::paper_defaults(ds_run, &sched, BaseWeighting::Golden)
                        .with_backend(RetrievalBackendKind::Batched.build(ds_run, opts))
                        .with_warm_start(true);
                sampler::sample(
                    &mut den as &mut dyn Denoiser,
                    ds_run,
                    &sched,
                    seed,
                    sampler::SamplerOpts::default(),
                )
                .final_sample()
                .to_vec()
            })
            .collect()
    };
    let resident_samples = run(&ds);
    let streamed_samples = run(&st);
    assert_eq!(
        resident_samples, streamed_samples,
        "streamed trajectories must be byte-identical to resident"
    );
    let src = st.source_stats().unwrap();
    assert!(src.evictions > 0, "the 1 MiB budget must evict: {src:?}");
    assert!(
        src.rows_streamed > ds.n as u64,
        "eviction must force re-streaming: {src:?}"
    );
    assert!(
        src.peak_row_bytes <= 1024 * 1024,
        "resident row bytes never exceed the budget: {src:?}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn streamed_baseline_fits_match_resident() {
    // Satellite: the full-support baseline denoisers (Optimal / PCA biased
    // + unbiased / Kamb) produce bit-identical posterior means on a
    // streamed corpus — the chunked shard-at-a-time passes preserve the
    // exact aggregation order
    let ds = small("mnist-sim", 220, 23);
    let dir = std::env::temp_dir().join("golddiff_it_streamed_baselines");
    std::fs::remove_dir_all(&dir).ok();
    let path = store::store_path(&dir, "mnist-sim");
    store::save_sharded(&ds, &path, 3).unwrap();
    let st = store::open_streaming(&path, 3, 0).unwrap();
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let mut rng = golddiff::util::rng::Pcg64::new(3);
    let x_t: Vec<f32> = (0..ds.d).map(|_| rng.normal()).collect();
    // best-populated class so the conditional arm always has support
    let cond = (0..ds.classes)
        .max_by_key(|&c| ds.class_rows[c].len())
        .unwrap() as u32;
    for kind in [
        DenoiserKind::Optimal,
        DenoiserKind::Pca,
        DenoiserKind::PcaUnbiased,
        DenoiserKind::Kamb,
        DenoiserKind::GoldDiff,
    ] {
        let mut a = kind.build(&ds, &sched);
        let mut b = kind.build(&st, &sched);
        for step in [0usize, 4, 9] {
            for class in [None, Some(cond)] {
                let ctx_r = StepContext {
                    ds: &ds,
                    sched: &sched,
                    step,
                    class,
                };
                let ctx_s = StepContext {
                    ds: &st,
                    sched: &sched,
                    step,
                    class,
                };
                let fa = a.denoise(&x_t, &ctx_r);
                let fb = b.denoise(&x_t, &ctx_s);
                assert_eq!(
                    fa.f_hat, fb.f_hat,
                    "{kind:?} step {step} class {class:?}: outputs diverged"
                );
                assert_eq!(fa.support, fb.support);
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncation_error_bound_holds_in_rust_stack() {
    // Theorem 1 checked end-to-end on real synthesized data.
    let ds = small("mnist-sim", 400, 7);
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let mut rng = golddiff::util::rng::Pcg64::new(1);
    for step in [0usize, 4, 9] {
        let x_t = sampler::renoise(ds.row(5), &sched, step, &mut rng);
        let q: Vec<f32> = x_t
            .iter()
            .map(|&v| v / sched.alpha_bar(step).sqrt())
            .collect();
        let scale = sched.logit_scale(step);
        let mut logits: Vec<f32> = (0..ds.n)
            .map(|i| {
                -ds.row(i)
                    .iter()
                    .zip(&q)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f32>()
                    * scale
            })
            .collect();
        // full vs top-k aggregate
        let k = 40;
        let mut order: Vec<usize> = (0..ds.n).collect();
        order.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]));
        let items_full: Vec<(f32, &[f32])> =
            (0..ds.n).map(|i| (logits[i], ds.row(i))).collect();
        let items_topk: Vec<(f32, &[f32])> = order[..k]
            .iter()
            .map(|&i| (logits[i], ds.row(i)))
            .collect();
        let (f_full, _) =
            golddiff::denoiser::softmax::ss_aggregate(ds.d, items_full.iter().copied());
        let (f_topk, _) =
            golddiff::denoiser::softmax::ss_aggregate(ds.d, items_topk.iter().copied());
        let err: f32 = f_full
            .iter()
            .zip(&f_topk)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        let radius = (0..ds.n)
            .map(|i| ds.row(i).iter().map(|v| v * v).sum::<f32>().sqrt())
            .fold(0.0f32, f32::max);
        let gap = logits[order[0]] - logits[order[k]];
        let bound = 2.0 * radius * (ds.n - k) as f32 * (-gap).exp();
        assert!(
            err <= bound + 1e-4,
            "step {step}: err {err} > bound {bound} (gap {gap})"
        );
        logits.clear();
    }
}
