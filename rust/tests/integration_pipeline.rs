//! Cross-module integration: dataset synthesis → store roundtrip → coarse
//! index → denoisers → sampler → oracle scoring, plus XLA-vs-CPU
//! cross-validation on an image preset.

use golddiff::data::store;
use golddiff::data::synthetic::preset;
use golddiff::denoiser::golddiff::{BaseWeighting, GoldDiff};
use golddiff::denoiser::{Denoiser, DenoiserKind, StepContext};
use golddiff::index::backend::{BackendOpts, RetrievalBackendKind};
use golddiff::metrics::EfficacyAccum;
use golddiff::oracle::GmmOracle;
use golddiff::sampler;
use golddiff::schedule::noise::{NoiseSchedule, ScheduleKind};
use golddiff::Dataset;

fn small(name: &str, n: usize, seed: u64) -> Dataset {
    let mut spec = preset(name).unwrap().clone();
    spec.n = n;
    Dataset::synthesize(&spec, seed)
}

#[test]
fn full_pipeline_moons_store_roundtrip_then_sample() {
    let dir = std::env::temp_dir().join("golddiff_it_pipeline");
    std::fs::remove_dir_all(&dir).ok();
    let ds = small("moons", 600, 3);
    store::save(&ds, &store::store_path(&dir, "moons")).unwrap();
    let ds = store::load(&store::store_path(&dir, "moons")).unwrap();
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);

    // every method produces a finite on-manifold-ish sample
    for kind in [
        DenoiserKind::Optimal,
        DenoiserKind::GoldDiff,
    ] {
        let mut den = kind.build(&ds, &sched);
        let traj = sampler::sample(den.as_mut(), &ds, &sched, 1, sampler::SamplerOpts::default());
        let x = traj.final_sample();
        assert!(x.iter().all(|v| v.is_finite()), "{kind:?}");
        let nearest: f32 = (0..ds.n)
            .map(|i| {
                ds.row(i)
                    .iter()
                    .zip(x)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f32>()
            })
            .fold(f32::INFINITY, f32::min);
        assert!(nearest < 0.5, "{kind:?} sample far from manifold: {nearest}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn golddiff_beats_or_matches_pca_and_runs_faster_cpu_path() {
    // The paper's core quantitative claim on the CPU reference path.
    let ds = small("cifar-sim", 1500, 5);
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let oracle = GmmOracle::new(ds.gmm.clone());

    let score = |kind: DenoiserKind| -> (f64, f64) {
        let mut den = kind.build(&ds, &sched);
        let mut acc = EfficacyAccum::new();
        let mut secs = 0.0;
        for s in 0..3u64 {
            let mut rng = golddiff::util::rng::Pcg64::new(s);
            let mut x = sampler::init_noise(ds.d, &mut rng);
            for step in 0..sched.steps {
                let target = oracle.denoise(&x, sched.alpha_bar(step));
                let ctx = StepContext {
                    ds: &ds,
                    sched: &sched,
                    step,
                    class: None,
                };
                let t0 = std::time::Instant::now();
                let out = den.denoise(&x, &ctx);
                secs += t0.elapsed().as_secs_f64();
                acc.update(&out.f_hat, &target);
                x = sampler::ddim_update(
                    &x,
                    &target,
                    sched.alpha_bar(step),
                    sched.alpha_prev(step),
                    0.0,
                    &mut rng,
                );
            }
        }
        (acc.mse(), secs)
    };

    let (mse_pca, t_pca) = score(DenoiserKind::Pca);
    let (mse_gold, t_gold) = score(DenoiserKind::GoldDiffPca);
    assert!(
        mse_gold <= mse_pca * 1.10,
        "GoldDiff mse {mse_gold} should match/beat PCA {mse_pca}"
    );
    assert!(
        t_gold < t_pca,
        "GoldDiff ({t_gold:.3}s) must be faster than full-scan PCA ({t_pca:.3}s)"
    );
}

#[test]
fn xla_and_cpu_paths_agree_on_image_preset() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        return;
    }
    // mnist-sim at its full preset size so manifest buckets match; shares
    // the `data/` cache with `make data` so repeat runs just load the store
    let dir = golddiff::benchlib::data_dir();
    let ds = store::load_or_synthesize(&dir, "mnist-sim", 0).unwrap();
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let rt = std::rc::Rc::new(
        golddiff::runtime::Runtime::new(std::path::Path::new("artifacts")).unwrap(),
    );

    let mut rng = golddiff::util::rng::Pcg64::new(9);
    let x_t: Vec<f32> = (0..ds.d).map(|_| rng.normal()).collect();

    use golddiff::coordinator::xla_denoiser::XlaDenoiser;
    for (kind, tol) in [
        (DenoiserKind::Optimal, 1e-3f32),
        (DenoiserKind::Wiener, 1e-3),
        (DenoiserKind::GoldDiff, 1e-3),
    ] {
        let mut xla = XlaDenoiser::new(std::rc::Rc::clone(&rt), &ds, kind).unwrap();
        let mut cpu = kind.build(&ds, &sched);
        for step in [2usize, 8] {
            let ctx = StepContext {
                ds: &ds,
                sched: &sched,
                step,
                class: None,
            };
            let fx = xla.denoise(&x_t, &ctx).f_hat;
            let fc = cpu.denoise(&x_t, &ctx).f_hat;
            let max_err = fx
                .iter()
                .zip(&fc)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(max_err < tol, "{kind:?} step {step}: max err {max_err}");
        }
    }
}

#[test]
fn determinism_matrix_backend_kernel_warmstart() {
    // Satellite: one seeded synthetic dataset stepped through the full
    // retrieval matrix — backend ∈ {flat, batched, cluster} × kernel ∈
    // {on, off} × warm_start ∈ {on, off} × shards ∈ {1, 2, 7} — must
    // produce byte-identical golden subsets for a tick group at every
    // sampling point, and byte-identical samples for a full
    // single-sequence trajectory. This is the engine's exactness contract:
    // every knob — including the corpus shard count, whose per-shard heaps
    // merge with a deterministic (distance, row id) tie-break — is a
    // performance lever, never a result lever.
    let ds = small("mnist-sim", 260, 11);
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let xs_data: Vec<Vec<f32>> = (0..6)
        .map(|i| {
            let mut rng = golddiff::util::rng::Pcg64::new(700 + i);
            (0..ds.d).map(|_| rng.normal()).collect()
        })
        .collect();

    let mut reference: Option<(Vec<Vec<Vec<u32>>>, Vec<f32>)> = None;
    for &backend in RetrievalBackendKind::all() {
        for kernel in [true, false] {
            for warm in [true, false] {
                for shards in [1usize, 2, 7] {
                    let opts = BackendOpts {
                        threads: 2,
                        clusters: 8,
                        kernel,
                        shards,
                        ..BackendOpts::default()
                    };
                    let build = || {
                        GoldDiff::paper_defaults(&ds, &sched, BaseWeighting::Golden)
                            .with_backend(backend.build(&ds, opts))
                            .with_warm_start(warm)
                    };
                    // (a) a 6-sequence tick group stepped 0..steps — the
                    // warm screen sees the previous step's subsets, as in
                    // serving
                    let mut gd = build();
                    let mut subsets = Vec::new();
                    for step in 0..sched.steps {
                        let ctx = StepContext {
                            ds: &ds,
                            sched: &sched,
                            step,
                            class: None,
                        };
                        let xs: Vec<&[f32]> = xs_data.iter().map(|x| x.as_slice()).collect();
                        let ctxs: Vec<&StepContext> = xs.iter().map(|_| &ctx).collect();
                        subsets.push(gd.golden_subsets(&xs, &ctxs));
                    }
                    // (b) a full single-sequence reverse trajectory
                    let mut den = build();
                    let traj = sampler::sample(
                        &mut den as &mut dyn Denoiser,
                        &ds,
                        &sched,
                        5,
                        sampler::SamplerOpts::default(),
                    );
                    let sample = traj.final_sample().to_vec();
                    let label =
                        format!("{}/kernel={kernel}/warm={warm}/shards={shards}", backend.name());
                    match &reference {
                        None => reference = Some((subsets, sample)),
                        Some((ref_subsets, ref_sample)) => {
                            assert_eq!(
                                ref_subsets, &subsets,
                                "{label}: golden subsets diverged"
                            );
                            assert_eq!(ref_sample, &sample, "{label}: samples diverged");
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn truncation_error_bound_holds_in_rust_stack() {
    // Theorem 1 checked end-to-end on real synthesized data.
    let ds = small("mnist-sim", 400, 7);
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let mut rng = golddiff::util::rng::Pcg64::new(1);
    for step in [0usize, 4, 9] {
        let x_t = sampler::renoise(ds.row(5), &sched, step, &mut rng);
        let q: Vec<f32> = x_t
            .iter()
            .map(|&v| v / sched.alpha_bar(step).sqrt())
            .collect();
        let scale = sched.logit_scale(step);
        let mut logits: Vec<f32> = (0..ds.n)
            .map(|i| {
                -ds.row(i)
                    .iter()
                    .zip(&q)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f32>()
                    * scale
            })
            .collect();
        // full vs top-k aggregate
        let k = 40;
        let mut order: Vec<usize> = (0..ds.n).collect();
        order.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]));
        let items_full: Vec<(f32, &[f32])> =
            (0..ds.n).map(|i| (logits[i], ds.row(i))).collect();
        let items_topk: Vec<(f32, &[f32])> = order[..k]
            .iter()
            .map(|&i| (logits[i], ds.row(i)))
            .collect();
        let (f_full, _) =
            golddiff::denoiser::softmax::ss_aggregate(ds.d, items_full.iter().copied());
        let (f_topk, _) =
            golddiff::denoiser::softmax::ss_aggregate(ds.d, items_topk.iter().copied());
        let err: f32 = f_full
            .iter()
            .zip(&f_topk)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        let radius = (0..ds.n)
            .map(|i| ds.row(i).iter().map(|v| v * v).sum::<f32>().sqrt())
            .fold(0.0f32, f32::max);
        let gap = logits[order[0]] - logits[order[k]];
        let bound = 2.0 * radius * (ds.n - k) as f32 * (-gap).exp();
        assert!(
            err <= bound + 1e-4,
            "step {step}: err {err} > bound {bound} (gap {gap})"
        );
        logits.clear();
    }
}
