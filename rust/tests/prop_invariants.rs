//! Cross-module property tests (hand-rolled `util::prop`, proptest-style):
//! the coordinator/retrieval invariants DESIGN.md §8 calls out, checked on
//! randomly generated datasets, schedules and budgets.

use golddiff::data::synthetic::preset;
use golddiff::denoiser::softmax::{exact_softmax, ss_aggregate};
use golddiff::denoiser::{DenoiserKind, StepContext};
use golddiff::index::backend::{
    BatchedScan, ClusterPruned, FlatScan, ProxyQuery, RetrievalBackend,
};
use golddiff::index::scan::ProxyIndex;
use golddiff::prop_assert;
use golddiff::schedule::budget::BudgetSchedule;
use golddiff::schedule::noise::{NoiseSchedule, ScheduleKind};
use golddiff::util::prop::{forall, gen};
use golddiff::Dataset;

#[test]
fn prop_retrieval_recall_golden_subset_is_true_topk_of_candidates() {
    // For any query, refine_top_k over the coarse candidates returns
    // exactly the k nearest of those candidates in full space, sorted.
    let mut spec = preset("mnist-sim").unwrap().clone();
    spec.n = 300;
    let ds = Dataset::synthesize(&spec, 21);
    let idx = ProxyIndex::default();
    forall(31, 25, |rng| {
        let m = gen::usize_in(rng, 4, 128);
        let k = gen::usize_in(rng, 1, m);
        let q = gen::vec_normal(rng, ds.d, 1.0);
        let qp = golddiff::data::synthetic::proxy_embed(&q, ds.h, ds.w, ds.c);
        let cands = idx.top_m(&ds, &qp, m);
        let golden = idx.refine_top_k(&ds, &q, &cands, k);
        prop_assert!(golden.len() == k.min(cands.len()), "size");
        // naive check within candidates
        let dist = |i: u32| -> f32 {
            ds.row(i as usize)
                .iter()
                .zip(&q)
                .map(|(a, b)| (a - b) * (a - b))
                .sum()
        };
        let mut naive = cands.clone();
        naive.sort_by(|&a, &b| dist(a).total_cmp(&dist(b)));
        naive.truncate(k);
        prop_assert!(golden == naive, "golden != naive topk");
        Ok(())
    });
}

#[test]
fn prop_budget_bucket_always_at_least_exact_budget() {
    forall(37, 100, |rng| {
        let n = gen::usize_in(rng, 500, 80_000);
        let buckets: Vec<usize> = (5..=17).map(|p| 1usize << p).collect();
        let b = BudgetSchedule::paper_defaults(n, &buckets);
        let steps = gen::usize_in(rng, 2, 50);
        let sched = NoiseSchedule::new(ScheduleKind::Cosine, steps);
        for i in 0..steps {
            let s = b.at(&sched, i);
            prop_assert!(
                s.k_bucket >= s.k || s.k_bucket == 1 << 17,
                "bucket {} < k {}",
                s.k_bucket,
                s.k
            );
            prop_assert!(s.m_bucket >= s.m || s.m_bucket == 1 << 17, "m bucket");
        }
        Ok(())
    });
}

#[test]
fn prop_streaming_softmax_is_permutation_invariant() {
    forall(41, 60, |rng| {
        let k = gen::usize_in(rng, 2, 100);
        let d = gen::usize_in(rng, 1, 16);
        let logits: Vec<f32> = (0..k).map(|_| rng.normal() * 8.0).collect();
        let rows: Vec<Vec<f32>> = (0..k).map(|_| gen::vec_normal(rng, d, 1.0)).collect();
        let items: Vec<(f32, &[f32])> = logits
            .iter()
            .copied()
            .zip(rows.iter().map(|r| r.as_slice()))
            .collect();
        let mut shuffled = items.clone();
        rng.shuffle(&mut shuffled);
        let (a, _) = ss_aggregate(d, items.iter().copied());
        let (b, _) = ss_aggregate(d, shuffled.iter().copied());
        for j in 0..d {
            prop_assert!((a[j] - b[j]).abs() < 1e-3, "dim {j}: {} vs {}", a[j], b[j]);
        }
        Ok(())
    });
}

#[test]
fn prop_posterior_weights_are_a_distribution() {
    forall(43, 60, |rng| {
        let k = gen::usize_in(rng, 1, 200);
        let logits: Vec<f32> = (0..k).map(|_| rng.normal() * 20.0).collect();
        let w = exact_softmax(&logits);
        let sum: f32 = w.iter().sum();
        prop_assert!((sum - 1.0).abs() < 1e-3, "sum {sum}");
        prop_assert!(w.iter().all(|&x| (0.0..=1.0 + 1e-6).contains(&x)), "range");
        Ok(())
    });
}

#[test]
fn prop_denoiser_outputs_always_finite_and_in_hull() {
    // Across random queries, noise levels and methods, f̂ is finite and a
    // convex combination (within the global bounding box) for unbiased
    // aggregators.
    let mut spec = preset("mnist-sim").unwrap().clone();
    spec.n = 250;
    let ds = Dataset::synthesize(&spec, 23);
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let (mut lo, mut hi) = (vec![f32::INFINITY; ds.d], vec![f32::NEG_INFINITY; ds.d]);
    for i in 0..ds.n {
        for (j, &v) in ds.row(i).iter().enumerate() {
            lo[j] = lo[j].min(v);
            hi[j] = hi[j].max(v);
        }
    }
    forall(47, 12, |rng| {
        let step = gen::usize_in(rng, 0, 9);
        let x_t = gen::vec_normal(rng, ds.d, 1.0);
        let kind = [
            DenoiserKind::Optimal,
            DenoiserKind::GoldDiff,
            DenoiserKind::PcaUnbiased,
        ][rng.below(3)];
        let mut den = kind.build(&ds, &sched);
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step,
            class: None,
        };
        let out = den.denoise(&x_t, &ctx);
        prop_assert!(out.f_hat.iter().all(|v| v.is_finite()), "{kind:?} non-finite");
        for j in (0..ds.d).step_by(37) {
            prop_assert!(
                out.f_hat[j] >= lo[j] - 1e-3 && out.f_hat[j] <= hi[j] + 1e-3,
                "{kind:?} dim {j} out of hull"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_retrieval_backends_agree_with_flat_reference() {
    // FlatScan ≡ BatchedScan ≡ unpruned/exact ClusterPruned: for random
    // queries (unconditional and class-conditional) every backend must
    // return the identical row-id list — the exactness guarantee the
    // engine's backend knob relies on.
    let mut spec = preset("cifar-sim").unwrap().clone();
    spec.n = 400;
    let ds = Dataset::synthesize(&spec, 31);
    let flat = FlatScan::scalar(2); // seed-semantics scalar reference
    let flat_kernel = FlatScan::new(2);
    let batched = BatchedScan::new(2);
    let batched_scalar = BatchedScan::scalar(2);
    let pruned = ClusterPruned::build(&ds, 12, 0, 5);
    let unpruned = ClusterPruned::build(&ds, 1, 0, 5); // single list = no pruning possible
    forall(59, 30, |rng| {
        let m = gen::usize_in(rng, 1, 128);
        let q = gen::vec_normal(rng, ds.proxy_d, 1.0);
        let class = if rng.below(3) == 0 {
            Some(rng.below(ds.classes) as u32)
        } else {
            None
        };
        let want = flat.top_m(&ds, &q, m, class);
        for (name, got) in [
            ("flat-kernel", flat_kernel.top_m(&ds, &q, m, class)),
            ("batched", batched.top_m(&ds, &q, m, class)),
            ("batched-scalar", batched_scalar.top_m(&ds, &q, m, class)),
            ("cluster-pruned", pruned.top_m(&ds, &q, m, class)),
            ("cluster-unpruned", unpruned.top_m(&ds, &q, m, class)),
        ] {
            prop_assert!(got == want, "{name} != flat (m={m} class={class:?})");
        }
        Ok(())
    });
}

#[test]
fn prop_tiled_kernel_groups_match_scalar_reference() {
    // The register-tiled kernel pass over ragged query groups (1..=9 spans
    // under, at and past the 8-query tile width) must return exactly what
    // the scalar per-query reference returns, conditional queries included.
    let mut spec = preset("cifar-sim").unwrap().clone();
    spec.n = 350;
    let ds = Dataset::synthesize(&spec, 41);
    let tiled = BatchedScan::new(2);
    let reference = FlatScan::scalar(2);
    forall(79, 15, |rng| {
        let b = gen::usize_in(rng, 1, 9);
        let m = gen::usize_in(rng, 1, 72);
        let qs: Vec<Vec<f32>> = (0..b).map(|_| gen::vec_normal(rng, ds.proxy_d, 1.0)).collect();
        let classes: Vec<Option<u32>> = (0..b)
            .map(|_| {
                if rng.below(4) == 0 {
                    Some(rng.below(ds.classes) as u32)
                } else {
                    None
                }
            })
            .collect();
        let queries: Vec<ProxyQuery> = qs
            .iter()
            .zip(&classes)
            .map(|(q, &class)| ProxyQuery { proxy: q, class })
            .collect();
        let grouped = tiled.top_m_batch(&ds, &queries, m);
        for (i, query) in queries.iter().enumerate() {
            let want = reference.top_m(&ds, query.proxy, m, query.class);
            prop_assert!(grouped[i] == want, "query {i} of {b} diverged (m={m})");
        }
        Ok(())
    });
}

#[test]
fn prop_batched_refine_ladder_matches_per_query_refine() {
    // The union-scan refine ladder is exact: per-query results equal the
    // scalar per-query refine for every pool shape, including empty and
    // singleton candidate sets.
    let mut spec = preset("mnist-sim").unwrap().clone();
    spec.n = 320;
    let ds = Dataset::synthesize(&spec, 43);
    let ladder = BatchedScan::new(2);
    let reference = FlatScan::scalar(2);
    forall(83, 15, |rng| {
        let b = gen::usize_in(rng, 1, 10);
        let k = gen::usize_in(rng, 1, 32);
        let qs_data: Vec<Vec<f32>> = (0..b).map(|_| gen::vec_normal(rng, ds.d, 1.0)).collect();
        let pools_data: Vec<Vec<u32>> = (0..b)
            .map(|i| match i % 3 {
                0 if i > 0 => Vec::new(),
                1 => vec![rng.below(ds.n) as u32],
                _ => {
                    let len = gen::usize_in(rng, 1, 96);
                    // distinct ids — candidate pools are top_m output
                    rng.choose_k(ds.n, len.min(ds.n))
                        .into_iter()
                        .map(|i| i as u32)
                        .collect()
                }
            })
            .collect();
        let qs: Vec<&[f32]> = qs_data.iter().map(|q| q.as_slice()).collect();
        let pools: Vec<&[u32]> = pools_data.iter().map(|p| p.as_slice()).collect();
        let got = ladder.refine_top_k_batch(&ds, &qs, &pools, k);
        for i in 0..b {
            let want = reference.refine_top_k(&ds, qs[i], pools[i], k);
            prop_assert!(
                got[i] == want,
                "refine {i}/{b} (k={k}, pool={}) diverged",
                pools[i].len()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_batched_group_scan_matches_per_query_scans() {
    // a whole batch group through one pass == each query scanned alone
    let mut spec = preset("mnist-sim").unwrap().clone();
    spec.n = 350;
    let ds = Dataset::synthesize(&spec, 37);
    let batched = BatchedScan::new(2);
    forall(67, 15, |rng| {
        let b = gen::usize_in(rng, 1, 12);
        let m = gen::usize_in(rng, 1, 64);
        let qs: Vec<Vec<f32>> = (0..b).map(|_| gen::vec_normal(rng, ds.proxy_d, 1.0)).collect();
        let classes: Vec<Option<u32>> = (0..b)
            .map(|_| {
                if rng.below(4) == 0 {
                    Some(rng.below(ds.classes) as u32)
                } else {
                    None
                }
            })
            .collect();
        let queries: Vec<ProxyQuery> = qs
            .iter()
            .zip(&classes)
            .map(|(q, &class)| ProxyQuery { proxy: q, class })
            .collect();
        let grouped = batched.top_m_batch(&ds, &queries, m);
        for (i, query) in queries.iter().enumerate() {
            let solo = batched.top_m(&ds, query.proxy, m, query.class);
            prop_assert!(grouped[i] == solo, "query {i} of {b} diverged (m={m})");
        }
        Ok(())
    });
}

#[test]
fn prop_preblocked_refine_matches_rowmajor_refine() {
    // Satellite: the pre-blocked (masked kernel tile) refine equals the
    // row-major reference refine across ragged full-resolution dims is
    // covered by kernel.rs unit tests; here the two ladders must agree on
    // the pool shapes the engine actually produces — sizes straddling the
    // mask widths (0 / 1 / 63 / 64 / 65) and pools carrying duplicates.
    let mut spec = preset("mnist-sim").unwrap().clone();
    spec.n = 320;
    let ds = Dataset::synthesize(&spec, 47);
    let preblocked = BatchedScan::new(2);
    let rowmajor = BatchedScan::new(2).with_refine_kernel(false);
    let per_query = FlatScan::scalar(2);
    forall(97, 12, |rng| {
        let k = gen::usize_in(rng, 1, 40);
        let sizes = [0usize, 1, 63, 64, 65];
        let nq = gen::usize_in(rng, 1, sizes.len());
        let qs_data: Vec<Vec<f32>> = (0..nq).map(|_| gen::vec_normal(rng, ds.d, 1.0)).collect();
        let pools_data: Vec<Vec<u32>> = (0..nq)
            .map(|i| {
                let len = sizes[i].min(ds.n);
                let mut p: Vec<u32> = rng
                    .choose_k(ds.n, len)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect();
                if p.len() > 3 && rng.below(2) == 0 {
                    p[2] = p[0]; // duplicates collapse in both ladders
                    p[3] = p[0];
                }
                p
            })
            .collect();
        let dup: Vec<bool> = pools_data
            .iter()
            .map(|p| {
                let distinct: std::collections::HashSet<&u32> = p.iter().collect();
                distinct.len() != p.len()
            })
            .collect();
        let qs: Vec<&[f32]> = qs_data.iter().map(|q| q.as_slice()).collect();
        let pools: Vec<&[u32]> = pools_data.iter().map(|p| p.as_slice()).collect();
        let got = preblocked.refine_top_k_batch(&ds, &qs, &pools, k);
        let want = rowmajor.refine_top_k_batch(&ds, &qs, &pools, k);
        for i in 0..nq {
            prop_assert!(
                got[i] == want[i],
                "preblocked != rowmajor (pool {} k={k})",
                pools[i].len()
            );
            // distinct pools additionally pin both ladders to the scalar
            // per-query refine (duplicate scoring is the known divergence
            // of the non-ladder path — see backend.rs docs)
            if !dup[i] {
                let per = per_query.refine_top_k(&ds, qs[i], pools[i], k);
                prop_assert!(got[i] == per, "ladder != per-query (pool {})", pools[i].len());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_heap_aware_ordering_is_order_invariant() {
    // Satellite: for seeds 0..8, the ordered scan returns identical top-k
    // ids AND identical f32 distances to the unordered scan, for every
    // backend that orders (batched, cluster) plus the flat reference.
    let mut spec = preset("mnist-sim").unwrap().clone();
    spec.n = 360;
    for seed in 0..8u64 {
        let ds = Dataset::synthesize(&spec, seed);
        let flat = FlatScan::scalar(2);
        let ordered: Vec<(&str, Box<dyn RetrievalBackend>)> = vec![
            ("batched", Box::new(BatchedScan::new(2))),
            ("cluster", Box::new(ClusterPruned::build(&ds, 10, 0, seed))),
        ];
        let unordered: Vec<(&str, Box<dyn RetrievalBackend>)> = vec![
            ("batched", Box::new(BatchedScan::new(2).with_ordering(false))),
            (
                "cluster",
                Box::new(ClusterPruned::build(&ds, 10, 0, seed).with_ordering(false)),
            ),
        ];
        let mut rng = golddiff::util::rng::Pcg64::new(1000 + seed);
        for case in 0..6 {
            let m = 1 + rng.below(96);
            let q: Vec<f32> = (0..ds.proxy_d).map(|_| rng.normal()).collect();
            let class = if case % 3 == 2 {
                Some(rng.below(ds.classes) as u32)
            } else {
                None
            };
            let pdist = |gid: u32| -> f32 {
                ds.proxy_row(gid as usize)
                    .iter()
                    .zip(&q)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum()
            };
            let reference = flat.top_m(&ds, &q, m, class);
            for ((name, ord), (_, unord)) in ordered.iter().zip(&unordered) {
                let a = ord.top_m(&ds, &q, m, class);
                let b = unord.top_m(&ds, &q, m, class);
                assert_eq!(a, b, "{name} seed={seed} m={m} class={class:?}: ids");
                let da: Vec<f32> = a.iter().map(|&g| pdist(g)).collect();
                let db: Vec<f32> = b.iter().map(|&g| pdist(g)).collect();
                assert_eq!(da, db, "{name} seed={seed}: distances");
                assert_eq!(a, reference, "{name} seed={seed}: vs flat reference");
            }
        }
    }
}

#[test]
fn prop_conditional_scan_never_leaks_other_classes() {
    let mut spec = preset("cifar-sim").unwrap().clone();
    spec.n = 300;
    let ds = Dataset::synthesize(&spec, 29);
    let idx = ProxyIndex::default();
    forall(53, 30, |rng| {
        let class = rng.below(ds.classes) as u32;
        let q = gen::vec_normal(rng, ds.proxy_d, 1.0);
        let m = gen::usize_in(rng, 1, 64);
        let got = idx.top_m_class(&ds, &q, m, class);
        prop_assert!(
            got.iter().all(|&i| ds.labels[i as usize] == class),
            "class leak"
        );
        Ok(())
    });
}
