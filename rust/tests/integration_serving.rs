//! Serving-stack integration: engine + server under concurrent load,
//! backpressure behaviour, conditional generation, and stats coherence.

use std::sync::Arc;

use golddiff::config::EngineConfig;
use golddiff::coordinator::Engine;
use golddiff::denoiser::DenoiserKind;
use golddiff::server::{Client, Server};
use golddiff::util::json::Json;

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn engine(preset: &str) -> Engine {
    let cfg = EngineConfig {
        preset: preset.into(),
        data_dir: std::env::temp_dir().join("golddiff_it_serving"),
        // these tests pin the legacy full-grid ddim serving contract
        // (step counts, steps_executed totals); the few-step engine paths
        // have their own coordinator tests
        solver: "ddim".into(),
        step_budget: 0,
        ..Default::default()
    };
    Engine::start(cfg).unwrap()
}

#[test]
fn sixteen_concurrent_mixed_requests_complete() {
    if !have_artifacts() {
        return;
    }
    let eng = engine("moons");
    // moons is 2-D: only the pixel-space variants exist for it
    let methods = [DenoiserKind::GoldDiff, DenoiserKind::Optimal];
    let rxs: Vec<_> = (0..16)
        .map(|i| {
            eng.submit(
                methods[i % methods.len()],
                i as u64,
                None,
            )
            .unwrap()
        })
        .collect();
    let mut ids = Vec::new();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.sample.iter().all(|v| v.is_finite()));
        assert_eq!(resp.steps.len(), 10);
        ids.push(resp.id);
    }
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 16, "duplicate or lost responses");

    let stats = eng.stats_json();
    assert!(stats.get("completed").unwrap().as_f64().unwrap() >= 16.0);
    assert!(stats.get("steps_executed").unwrap().as_f64().unwrap() >= 160.0);
    eng.shutdown();
}

#[test]
fn determinism_under_concurrency() {
    if !have_artifacts() {
        return;
    }
    let eng = engine("moons");
    // run the same seed alone and under load — identical outputs
    let alone = eng.generate(DenoiserKind::GoldDiff, 77, None).unwrap();
    let rxs: Vec<_> = (0..8)
        .map(|i| eng.submit(DenoiserKind::GoldDiff, 70 + i, None).unwrap())
        .collect();
    let batch: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
    let under_load = batch.iter().find(|r| {
        // seed 77 is the 8th (70..78); find by matching sample to alone
        r.sample == alone.sample
    });
    assert!(
        under_load.is_some(),
        "seed-77 output changed under concurrent batching"
    );
    eng.shutdown();
}

#[test]
fn server_round_trip_with_multiple_clients() {
    if !have_artifacts() {
        return;
    }
    let eng = Arc::new(engine("moons"));
    let server = Server::start(Arc::clone(&eng), "127.0.0.1:0").unwrap();
    let addr = server.addr;
    let handles: Vec<_> = (0..3)
        .map(|c| {
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                for i in 0..3 {
                    let resp = client
                        .generate("golddiff", (c * 10 + i) as u64, None)
                        .unwrap();
                    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
                    assert_eq!(resp.get("sample").unwrap().as_arr().unwrap().len(), 2);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let mut client = Client::connect(&addr).unwrap();
    let stats = client.stats().unwrap();
    assert!(
        stats
            .get("stats")
            .unwrap()
            .get("completed")
            .unwrap()
            .as_f64()
            .unwrap()
            >= 9.0
    );
    server.stop();
}

#[test]
fn latency_telemetry_is_sane() {
    if !have_artifacts() {
        return;
    }
    let eng = engine("moons");
    let resp = eng.generate(DenoiserKind::GoldDiff, 5, None).unwrap();
    assert!(resp.latency_secs >= resp.queue_secs);
    for step in &resp.steps {
        assert!(step.scan_secs >= 0.0 && step.dispatch_secs > 0.0);
        assert!(step.k_bucket >= step.k_used);
        assert!(step.m_used >= step.k_used);
    }
    // entropy collapses along the trajectory (posterior concentration)
    let first = resp.steps.first().unwrap().entropy;
    let last = resp.steps.last().unwrap().entropy;
    assert!(
        last < first,
        "posterior entropy should collapse: {first} -> {last}"
    );
    eng.shutdown();
}
